//! Results registry — what the service hands the coordinator and CLI, and
//! what `patsma service retune` warm-starts from.
//!
//! Each completed session yields a [`SessionReport`]; a batch run yields a
//! [`ServiceReport`] (sessions + persisted [`SessionState`]s + a
//! cache-counter snapshot). The registry serialises to a plain text file
//! (the offline build has no serde) so a later `patsma service
//! report|retune` process can consume results from an earlier `patsma
//! service run`.
//!
//! ## Format v2
//!
//! Line-oriented: a magic header, then one whitespace-separated record per
//! line. Every record is `<type> key=value key=value ...`:
//!
//! ```text
//! # patsma-service-registry v2
//! cache hits=3 misses=29 entries=29
//! session id=s0 workload=synthetic/... optimizer=csa evals=20 ... warm=0
//! state id=s0 workload=synthetic/... fingerprint=... env=threads=8/os=linux ...
//! ```
//!
//! Compatibility rules:
//! * **unknown keys are ignored** on load — newer writers can add fields
//!   without breaking older readers (pinned by tests);
//! * **v1 files still load** (the positional format of the first release);
//! * [`ServiceReport::from_text`] is strict about malformed records, while
//!   [`ServiceReport::from_text_lenient`] skips them and reports what it
//!   skipped — corrupt-file recovery for long-lived registries.

use super::cache::CacheStats;
use super::state::SessionState;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Magic first line of a v2 registry file.
const HEADER_V2: &str = "# patsma-service-registry v2";

/// Magic first line of the original positional format (still loadable).
const HEADER_V1: &str = "# patsma-service-registry v1";

/// One finished tuning session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionReport {
    /// Caller-chosen session label (no whitespace).
    pub id: String,
    /// Workload descriptor (the fingerprint input; no whitespace).
    pub workload: String,
    /// Optimizer name.
    pub optimizer: String,
    /// Optimizer evaluations consumed (cache hits included — the optimizer
    /// cannot tell a cached cost from a fresh one).
    pub evaluations: u64,
    /// Target iterations actually executed (cache hits excluded — that is
    /// the point of the cache).
    pub target_iterations: u64,
    /// Batch evaluations answered from the shared cache.
    pub cache_hits: u64,
    /// Batch evaluations that ran the target.
    pub cache_misses: u64,
    /// Best measured point (user domain; quantised for integer domains,
    /// exact for float domains; cache-key coordinates for typed domains).
    pub best_point: Vec<f64>,
    /// The typed decoded cell for search-space sessions (categorical
    /// values by name, e.g. `dynamic,32`); `None` for plain numeric
    /// domains — and for records written before format v2 grew the `label`
    /// key, which still load (back-compat: unknown/missing keys).
    pub best_label: Option<String>,
    /// Best measured cost.
    pub best_cost: f64,
    /// Session wall-clock seconds.
    pub wall_secs: f64,
    /// Whether the session was seeded from persisted state.
    pub warm_started: bool,
}

/// A batch of session results plus persisted states and cache counters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceReport {
    /// Per-session results, spec order.
    pub sessions: Vec<SessionReport>,
    /// Persisted optimizer states (one per session whose optimizer supports
    /// export; latest run wins per session id).
    pub states: Vec<SessionState>,
    /// Cache counters at the end of the batch.
    pub cache: CacheStats,
}

fn fmt_point(point: &[f64]) -> String {
    if point.is_empty() {
        "-".to_string()
    } else {
        point
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

fn parse_point(text: &str) -> Result<Vec<f64>> {
    if text == "-" {
        return Ok(Vec::new());
    }
    text.split(',')
        .map(|v| {
            v.parse::<f64>()
                .with_context(|| format!("bad point coord {v:?}"))
        })
        .collect()
}

impl ServiceReport {
    /// Total cache hits across the reported sessions.
    pub fn session_cache_hits(&self) -> u64 {
        self.sessions.iter().map(|s| s.cache_hits).sum()
    }

    /// Persisted state for a session id, if any.
    pub fn state_for(&self, id: &str) -> Option<&SessionState> {
        self.states.iter().find(|s| s.id == id)
    }

    /// Render as a markdown report (the `patsma service report` output).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "\n| session | workload | optimizer | warm | evals | target iters | cache hits | \
             best point | best cost | wall |\n|---|---|---|---|---|---|---|---|---|---|\n",
        );
        for s in &self.sessions {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {:.6e} | {} |\n",
                s.id,
                s.workload,
                s.optimizer,
                if s.warm_started { "yes" } else { "no" },
                s.evaluations,
                s.target_iterations,
                s.cache_hits,
                // Typed sessions show the decoded cell (categories by
                // name); numeric sessions the raw point.
                s.best_label
                    .clone()
                    .unwrap_or_else(|| fmt_point(&s.best_point)),
                s.best_cost,
                crate::bench::fmt_time(s.wall_secs),
            ));
        }
        let c = &self.cache;
        out.push_str(&format!(
            "\nsessions: {}; session cache hits: {}; shared cache: {} hits / {} misses \
             ({:.1}% hit rate), {} entries; persisted states: {}\n",
            self.sessions.len(),
            self.session_cache_hits(),
            c.hits,
            c.misses,
            100.0 * c.hit_rate(),
            c.entries,
            self.states.len(),
        ));
        out
    }

    /// Serialise to the v2 registry format.
    pub fn to_text(&self) -> String {
        let mut out = format!("{HEADER_V2}\n");
        out.push_str(&format!(
            "cache hits={} misses={} entries={}\n",
            self.cache.hits, self.cache.misses, self.cache.entries
        ));
        for s in &self.sessions {
            out.push_str(&format!(
                "session id={} workload={} optimizer={} evals={} iters={} hits={} misses={} \
                 best={} cost={} wall={} warm={}",
                s.id,
                s.workload,
                s.optimizer,
                s.evaluations,
                s.target_iterations,
                s.cache_hits,
                s.cache_misses,
                fmt_point(&s.best_point),
                s.best_cost,
                s.wall_secs,
                if s.warm_started { 1 } else { 0 },
            ));
            if let Some(label) = &s.best_label {
                out.push_str(&format!(" label={label}"));
            }
            out.push('\n');
        }
        for st in &self.states {
            let body = st
                .to_kv()
                .into_iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            out.push_str(&format!("state {body}\n"));
        }
        out
    }

    /// Parse a registry (v2 `key=value` or legacy v1 positional). Strict:
    /// malformed records are an error (use
    /// [`from_text_lenient`](Self::from_text_lenient) to recover instead);
    /// unknown *keys* inside a known record are ignored.
    pub fn from_text(text: &str) -> Result<Self> {
        let (report, skipped) = Self::parse(text, false)?;
        debug_assert!(skipped.is_empty(), "strict parse cannot skip");
        Ok(report)
    }

    /// Parse, skipping malformed records instead of failing. Returns the
    /// recovered report and one human-readable note per skipped line. The
    /// header must still match — without it the file is not a registry and
    /// "recovering" it would fabricate an empty report from garbage.
    pub fn from_text_lenient(text: &str) -> Result<(Self, Vec<String>)> {
        Self::parse(text, true)
    }

    fn parse(text: &str, lenient: bool) -> Result<(Self, Vec<String>)> {
        let mut lines = text.lines();
        let version = match lines.next().map(str::trim) {
            Some(h) if h == HEADER_V2 => 2,
            Some(h) if h == HEADER_V1 => 1,
            other => bail!("not a service registry (header {other:?})"),
        };
        let mut cache = CacheStats {
            hits: 0,
            misses: 0,
            entries: 0,
        };
        let mut sessions = Vec::new();
        let mut states = Vec::new();
        let mut skipped = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parsed = if version == 1 {
                parse_v1_record(line, &mut cache, &mut sessions)
            } else {
                parse_v2_record(line, &mut cache, &mut sessions, &mut states)
            };
            if let Err(e) = parsed {
                if lenient {
                    skipped.push(format!("line {}: {e:#}", lineno + 2));
                } else {
                    return Err(e.context(format!("registry line {}", lineno + 2)));
                }
            }
        }
        Ok((
            Self {
                sessions,
                states,
                cache,
            },
            skipped,
        ))
    }

    /// Write the registry to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing registry {}", path.display()))
    }

    /// Read a registry from `path` (strict).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading registry {}", path.display()))?;
        Self::from_text(&text)
    }

    /// Read a registry from `path`, recovering what a corrupted file still
    /// holds; returns the skipped-line notes alongside.
    pub fn load_lenient(path: &Path) -> Result<(Self, Vec<String>)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading registry {}", path.display()))?;
        Self::from_text_lenient(&text)
    }
}

/// Split a v2 record body into `(key, value)` pairs; values may themselves
/// contain `=` (descriptors), so only the first `=` per token splits.
fn split_kv(tokens: &[&str]) -> Result<Vec<(String, String)>> {
    tokens
        .iter()
        .map(|t| {
            t.split_once('=')
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .with_context(|| format!("token {t:?} is not key=value"))
        })
        .collect()
}

fn kv_get<'a>(pairs: &'a [(String, String)], key: &str) -> Result<&'a str> {
    kv_opt(pairs, key).with_context(|| format!("missing {key:?}"))
}

fn kv_opt<'a>(pairs: &'a [(String, String)], key: &str) -> Option<&'a str> {
    pairs
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
}

fn parse_v2_record(
    line: &str,
    cache: &mut CacheStats,
    sessions: &mut Vec<SessionReport>,
    states: &mut Vec<SessionState>,
) -> Result<()> {
    let tokens: Vec<&str> = line.split_whitespace().collect();
    let pairs = split_kv(&tokens[1..])?;
    match tokens[0] {
        "cache" => {
            *cache = CacheStats {
                hits: kv_get(&pairs, "hits")?.parse().context("bad hits")?,
                misses: kv_get(&pairs, "misses")?.parse().context("bad misses")?,
                entries: kv_get(&pairs, "entries")?.parse().context("bad entries")?,
            };
        }
        "session" => {
            sessions.push(SessionReport {
                id: kv_get(&pairs, "id")?.to_string(),
                workload: kv_get(&pairs, "workload")?.to_string(),
                optimizer: kv_get(&pairs, "optimizer")?.to_string(),
                evaluations: kv_get(&pairs, "evals")?.parse().context("bad evals")?,
                target_iterations: kv_get(&pairs, "iters")?.parse().context("bad iters")?,
                cache_hits: kv_get(&pairs, "hits")?.parse().context("bad hits")?,
                cache_misses: kv_get(&pairs, "misses")?.parse().context("bad misses")?,
                best_point: parse_point(kv_get(&pairs, "best")?)?,
                best_label: kv_opt(&pairs, "label").map(str::to_string),
                best_cost: kv_get(&pairs, "cost")?.parse().context("bad cost")?,
                wall_secs: kv_get(&pairs, "wall")?.parse().context("bad wall")?,
                warm_started: kv_get(&pairs, "warm").map(|v| v == "1").unwrap_or(false),
            });
        }
        "state" => {
            let borrowed: Vec<(&str, &str)> = pairs
                .iter()
                .map(|(k, v)| (k.as_str(), v.as_str()))
                .collect();
            states.push(SessionState::from_kv(&borrowed)?);
        }
        other => bail!("unrecognised record {other:?}"),
    }
    Ok(())
}

/// The original positional format: `cache H M E` and 11-field `session`
/// lines. Loaded for back-compat; saving always writes v2.
fn parse_v1_record(
    line: &str,
    cache: &mut CacheStats,
    sessions: &mut Vec<SessionReport>,
) -> Result<()> {
    let f: Vec<&str> = line.split_whitespace().collect();
    match f[0] {
        "cache" if f.len() == 4 => {
            *cache = CacheStats {
                hits: f[1].parse().context("bad hits")?,
                misses: f[2].parse().context("bad misses")?,
                entries: f[3].parse().context("bad entries")?,
            };
        }
        "session" if f.len() == 11 => {
            sessions.push(SessionReport {
                id: f[1].to_string(),
                workload: f[2].to_string(),
                optimizer: f[3].to_string(),
                evaluations: f[4].parse().context("bad evaluations")?,
                target_iterations: f[5].parse().context("bad iters")?,
                cache_hits: f[6].parse().context("bad cache hits")?,
                cache_misses: f[7].parse().context("bad cache misses")?,
                best_point: parse_point(f[8])?,
                best_label: None,
                best_cost: f[9].parse().context("bad best cost")?,
                wall_secs: f[10].parse().context("bad wall seconds")?,
                warm_started: false,
            });
        }
        _ => bail!("unrecognised record {line:?}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::OptimizerState;
    use crate::service::state::EnvFingerprint;

    fn sample_state(id: &str) -> SessionState {
        SessionState {
            id: id.into(),
            workload: "synthetic/opt=48/dim=1/lo=1/hi=128/kind=int".into(),
            fingerprint: 123_456,
            env: EnvFingerprint::with_threads(8),
            optimizer: "csa".into(),
            num_opt: 4,
            max_iter: 8,
            seed: 42,
            ignore: 0,
            best_point: vec![47.0],
            best_cost: 1.0104,
            opt_state: OptimizerState {
                optimizer: "csa".into(),
                best_internal: vec![-0.28],
                best_cost: 1.0104,
                temperatures: Some((0.125, 1.75)),
                points: vec![vec![-0.28], vec![0.5]],
            },
        }
    }

    fn sample() -> ServiceReport {
        ServiceReport {
            sessions: vec![
                SessionReport {
                    id: "s0".into(),
                    workload: "synthetic/best=48/dim=1".into(),
                    optimizer: "csa".into(),
                    evaluations: 20,
                    target_iterations: 17,
                    cache_hits: 3,
                    cache_misses: 17,
                    best_point: vec![47.0],
                    best_label: None,
                    best_cost: 1.0104,
                    wall_secs: 0.002,
                    warm_started: false,
                },
                SessionReport {
                    id: "s1".into(),
                    workload: "synthetic/best=24/dim=2".into(),
                    optimizer: "nelder-mead".into(),
                    evaluations: 12,
                    target_iterations: 12,
                    cache_hits: 0,
                    cache_misses: 12,
                    best_point: vec![25.5, 23.0],
                    best_label: Some("dynamic,23".into()),
                    best_cost: 2.1,
                    wall_secs: 0.001,
                    warm_started: true,
                },
            ],
            states: vec![sample_state("s0")],
            cache: CacheStats {
                hits: 3,
                misses: 29,
                entries: 29,
            },
        }
    }

    #[test]
    fn text_roundtrip_is_lossless() {
        let r = sample();
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn save_load_roundtrip() {
        let r = sample();
        let path = std::env::temp_dir().join("patsma-registry-test.txt");
        r.save(&path).unwrap();
        let loaded = ServiceReport::load(&path).unwrap();
        assert_eq!(loaded, r);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_keys_are_ignored_forward_compat() {
        // A future writer adds fields; this reader must not choke on them.
        let mut text = String::from(
            "# patsma-service-registry v2\n\
             cache hits=1 misses=2 entries=2 compression=zstd\n",
        );
        text.push_str(
            "session id=s9 workload=w optimizer=csa evals=4 iters=4 hits=0 misses=4 \
             best=3 cost=0.5 wall=0.01 warm=0 gpu_time=0.3 battery=full\n",
        );
        let r = ServiceReport::from_text(&text).unwrap();
        assert_eq!(r.sessions.len(), 1);
        assert_eq!(r.sessions[0].id, "s9");
        assert_eq!(r.cache.misses, 2);
    }

    #[test]
    fn missing_warm_key_defaults_to_cold() {
        let text = "# patsma-service-registry v2\n\
                    session id=s0 workload=w optimizer=csa evals=1 iters=1 hits=0 misses=1 \
                    best=2 cost=0.1 wall=0.01\n";
        let r = ServiceReport::from_text(text).unwrap();
        assert!(!r.sessions[0].warm_started);
    }

    #[test]
    fn v1_files_still_load() {
        let text = "# patsma-service-registry v1\n\
                    cache 3 29 29\n\
                    session s0 synthetic/best=48/dim=1 csa 20 17 3 17 47 1.0104 0.002\n";
        let r = ServiceReport::from_text(text).unwrap();
        assert_eq!(r.sessions.len(), 1);
        assert_eq!(r.sessions[0].best_point, vec![47.0]);
        assert_eq!(r.cache.hits, 3);
        assert!(r.states.is_empty());
        assert!(!r.sessions[0].warm_started);
        assert_eq!(
            r.sessions[0].best_label, None,
            "old numeric records have no typed label"
        );
    }

    #[test]
    fn typed_labels_roundtrip_and_render() {
        // The text roundtrip already covers Some/None (sample has both);
        // check the rendered table prefers the typed cell.
        let r = sample();
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed.sessions[0].best_label, None);
        assert_eq!(parsed.sessions[1].best_label, Some("dynamic,23".into()));
        let table = r.render();
        assert!(table.contains("| dynamic,23 |"), "{table}");
        // Records without the label key (pre-joint writers) still load.
        let text = "# patsma-service-registry v2\n\
                    session id=old workload=w optimizer=csa evals=1 iters=1 hits=0 misses=1 \
                    best=2 cost=0.1 wall=0.01 warm=0\n";
        let old = ServiceReport::from_text(text).unwrap();
        assert_eq!(old.sessions[0].best_label, None);
    }

    #[test]
    fn lenient_parse_recovers_around_corruption() {
        let good = sample();
        let mut text = good.to_text();
        // Corrupt the middle: a truncated record and binary junk.
        text.push_str("session id=broken workload=w optimizer=csa evals=NOTANUMBER\n");
        text.push_str("\u{0}\u{1}garbage record here\n");
        text.push_str(
            "session id=tail workload=w optimizer=sa evals=2 iters=2 hits=0 misses=2 \
             best=5 cost=0.25 wall=0.001 warm=0\n",
        );
        // Strict parse refuses...
        assert!(ServiceReport::from_text(&text).is_err());
        // ...lenient parse keeps everything salvageable.
        let (r, skipped) = ServiceReport::from_text_lenient(&text).unwrap();
        assert_eq!(skipped.len(), 2, "{skipped:?}");
        assert_eq!(r.sessions.len(), good.sessions.len() + 1);
        assert_eq!(r.sessions.last().unwrap().id, "tail");
        assert_eq!(r.states.len(), 1);
    }

    #[test]
    fn lenient_parse_still_requires_the_header() {
        assert!(ServiceReport::from_text_lenient("random junk\nmore junk\n").is_err());
    }

    #[test]
    fn render_reports_cache_hits_and_states() {
        let text = sample().render();
        assert!(text.contains("cache hits"), "{text}");
        assert!(text.contains("session cache hits: 3"), "{text}");
        assert!(text.contains("| s0 |"), "{text}");
        assert!(text.contains("persisted states: 1"), "{text}");
        assert!(text.contains("| yes |"), "{text}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ServiceReport::from_text("nonsense").is_err());
        assert!(
            ServiceReport::from_text("# patsma-service-registry v2\nbogus line here").is_err()
        );
    }

    #[test]
    fn float_best_points_roundtrip_exactly() {
        let mut r = sample();
        r.sessions[0].best_point = vec![32.248_737_510_186_3, 0.125];
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed.sessions[0].best_point, r.sessions[0].best_point);
    }
}
