//! Results registry — what the service hands the coordinator and CLI, and
//! what `patsma service retune` warm-starts from.
//!
//! Each completed session yields a [`SessionReport`]; a batch run yields a
//! [`ServiceReport`] (sessions + persisted [`SessionState`]s + a
//! cache-counter snapshot). The registry serialises to a plain text file
//! (the offline build has no serde) so a later `patsma service
//! report|retune` process can consume results from an earlier `patsma
//! service run`.
//!
//! ## Format v2
//!
//! Line-oriented: a magic header, then one whitespace-separated record per
//! line. Every record is `<type> key=value key=value ...`:
//!
//! ```text
//! # patsma-service-registry v2
//! cache hits=3 misses=29 entries=29 evictions=0 cap=65536
//! session id=s0 workload=synthetic/... optimizer=csa evals=20 ... warm=0
//! state id=s0 workload=synthetic/... fingerprint=... env=threads=8/os=linux ...
//! ```
//!
//! The same `key=value` codec carries the daemon's wire payloads
//! ([`crate::service::proto`]) — a session record means the same thing in
//! a registry file and in a socket frame.
//!
//! Compatibility rules:
//! * **unknown keys are preserved** on load — newer writers can add
//!   fields without breaking older readers, and a load → snapshot
//!   roundtrip through an older build keeps them (`extra` on
//!   [`SessionReport`]/[`SessionState`]; pinned by tests). Unknown
//!   *record types* whose body parses as `key=value` are carried
//!   verbatim in [`ServiceReport::extras`]. The one exception is the
//!   `cache` record: its counters are a live snapshot the service
//!   rewrites wholesale, so stale unknown cache keys are dropped rather
//!   than resurrected;
//! * **v1 files still load** (the positional format of the first release),
//!   and v2 files written before the cache grew `evictions`/`cap` load
//!   with those counters zeroed;
//! * [`ServiceReport::from_text`] is strict about malformed records, while
//!   [`ServiceReport::from_text_lenient`] skips them and reports what it
//!   skipped — corrupt-file recovery for long-lived registries.
//!
//! Failures are typed [`PatsmaError`]s: `Registry` for malformed records
//! (with the 1-based line number attached), `Io` for filesystem errors.

use super::cache::CacheStats;
use super::state::SessionState;
use crate::adaptive::table::TableEntry;
use crate::error::PatsmaError;
use crate::space::FrontEntry;
use std::path::Path;

/// Magic first line of a v2 registry file.
const HEADER_V2: &str = "# patsma-service-registry v2";

/// Magic first line of the original positional format (still loadable).
const HEADER_V1: &str = "# patsma-service-registry v1";

/// One finished tuning session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionReport {
    /// Caller-chosen session label (no whitespace).
    pub id: String,
    /// Workload descriptor (the fingerprint input; no whitespace).
    pub workload: String,
    /// Optimizer name.
    pub optimizer: String,
    /// Optimizer evaluations consumed (cache hits included — the optimizer
    /// cannot tell a cached cost from a fresh one).
    pub evaluations: u64,
    /// Target iterations actually executed (cache hits excluded — that is
    /// the point of the cache).
    pub target_iterations: u64,
    /// Batch evaluations answered from the shared cache.
    pub cache_hits: u64,
    /// Batch evaluations that ran the target.
    pub cache_misses: u64,
    /// Best measured point (user domain; quantised for integer domains,
    /// exact for float domains; cache-key coordinates for typed domains).
    pub best_point: Vec<f64>,
    /// The typed decoded cell for search-space sessions (categorical
    /// values by name, e.g. `dynamic,32`); `None` for plain numeric
    /// domains — and for records written before format v2 grew the `label`
    /// key, which still load (back-compat: unknown/missing keys).
    pub best_label: Option<String>,
    /// Best measured cost.
    pub best_cost: f64,
    /// Session wall-clock seconds.
    pub wall_secs: f64,
    /// Whether the session was seeded from persisted state.
    pub warm_started: bool,
    /// Keys this build does not understand, preserved verbatim so a load →
    /// snapshot roundtrip through an older binary does not destroy fields a
    /// newer writer added (module compatibility rules).
    pub extra: Vec<(String, String)>,
}

impl SessionReport {
    /// Serialise to the v2 `key=value` pairs — the one codec shared by the
    /// registry file and the daemon wire protocol. Order is stable (the
    /// registry is diffable); the optional `label` key comes last.
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let mut kv = vec![
            ("id".to_string(), self.id.clone()),
            ("workload".to_string(), self.workload.clone()),
            ("optimizer".to_string(), self.optimizer.clone()),
            ("evals".to_string(), self.evaluations.to_string()),
            ("iters".to_string(), self.target_iterations.to_string()),
            ("hits".to_string(), self.cache_hits.to_string()),
            ("misses".to_string(), self.cache_misses.to_string()),
            ("best".to_string(), fmt_point(&self.best_point)),
            ("cost".to_string(), format!("{}", self.best_cost)),
            ("wall".to_string(), format!("{}", self.wall_secs)),
            (
                "warm".to_string(),
                if self.warm_started { "1" } else { "0" }.to_string(),
            ),
        ];
        if let Some(label) = &self.best_label {
            kv.push(("label".to_string(), label.clone()));
        }
        kv.extend(self.extra.iter().cloned());
        kv
    }

    /// Keys `to_kv`/`from_kv` understand; anything else lands in `extra`.
    const KNOWN_KEYS: [&'static str; 12] = [
        "id", "workload", "optimizer", "evals", "iters", "hits", "misses", "best", "label",
        "cost", "wall", "warm",
    ];

    /// Parse from v2 `key=value` pairs (unknown keys preserved in `extra`,
    /// `warm` and `label` optional — see module compatibility rules).
    pub fn from_kv(pairs: &[(String, String)]) -> Result<Self, PatsmaError> {
        Ok(SessionReport {
            id: kv_get(pairs, "id")?.to_string(),
            workload: kv_get(pairs, "workload")?.to_string(),
            optimizer: kv_get(pairs, "optimizer")?.to_string(),
            evaluations: kv_num(pairs, "evals")?,
            target_iterations: kv_num(pairs, "iters")?,
            cache_hits: kv_num(pairs, "hits")?,
            cache_misses: kv_num(pairs, "misses")?,
            best_point: parse_point(kv_get(pairs, "best")?)?,
            best_label: kv_opt(pairs, "label").map(str::to_string),
            best_cost: kv_num(pairs, "cost")?,
            wall_secs: kv_num(pairs, "wall")?,
            warm_started: kv_opt(pairs, "warm") == Some("1"),
            extra: pairs
                .iter()
                .filter(|(k, _)| !Self::KNOWN_KEYS.contains(&k.as_str()))
                .cloned()
                .collect(),
        })
    }
}

/// One non-dominated cell of a session's Pareto front, as persisted in the
/// registry (`pareto` records, one line per cell). Older builds see an
/// unknown record type and carry the lines verbatim in
/// [`ServiceReport::extras`], so a snapshot through an old binary does not
/// destroy a newer writer's fronts.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoRecord {
    /// Owning session id.
    pub session: String,
    /// The cell's cache-key coordinates.
    pub cell: Vec<f64>,
    /// Typed rendering of the cell when the space is known (`dynamic,32`).
    pub label: Option<String>,
    /// Median cost of the cell's samples.
    pub median: f64,
    /// p95 tail cost.
    pub p95: f64,
    /// Efficiency proxy (work per core-second; higher is better).
    pub efficiency: f64,
    /// Scalarized cost under the owning session's objective weights.
    pub scalar: f64,
}

impl ParetoRecord {
    /// A record from one front entry of session `session`.
    pub fn from_entry(session: &str, entry: &FrontEntry) -> Self {
        Self {
            session: session.to_string(),
            cell: entry.key.clone(),
            label: entry.label.clone(),
            median: entry.cost.median,
            p95: entry.cost.p95,
            efficiency: entry.cost.efficiency,
            scalar: entry.scalar,
        }
    }

    /// Serialise to the v2 `key=value` pairs (optional `label` last).
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let mut kv = vec![
            ("id".to_string(), self.session.clone()),
            ("cell".to_string(), fmt_point(&self.cell)),
            ("median".to_string(), format!("{}", self.median)),
            ("p95".to_string(), format!("{}", self.p95)),
            ("eff".to_string(), format!("{}", self.efficiency)),
            ("scalar".to_string(), format!("{}", self.scalar)),
        ];
        if let Some(label) = &self.label {
            kv.push(("label".to_string(), label.clone()));
        }
        kv
    }

    /// The full registry line (`pareto id=... cell=... ...`).
    pub fn to_record(&self) -> String {
        let body = self
            .to_kv()
            .into_iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        format!("pareto {body}")
    }

    /// Parse from v2 `key=value` pairs.
    pub fn from_kv(pairs: &[(String, String)]) -> Result<Self, PatsmaError> {
        Ok(Self {
            session: kv_get(pairs, "id")?.to_string(),
            cell: parse_point(kv_get(pairs, "cell")?)?,
            label: kv_opt(pairs, "label").map(str::to_string),
            median: kv_num(pairs, "median")?,
            p95: kv_num(pairs, "p95")?,
            efficiency: kv_num(pairs, "eff")?,
            scalar: kv_num(pairs, "scalar")?,
        })
    }
}

/// A batch of session results plus persisted states and cache counters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceReport {
    /// Per-session results, spec order.
    pub sessions: Vec<SessionReport>,
    /// Persisted optimizer states (one per session whose optimizer supports
    /// export; latest run wins per session id).
    pub states: Vec<SessionState>,
    /// Cache counters at the end of the batch.
    pub cache: CacheStats,
    /// Converged tuned-table cells (`table` records) keyed by execution
    /// context — what exact-revisit bypass and warm restarts load from.
    pub table: Vec<TableEntry>,
    /// Pareto-front cells of non-scalar-objective sessions (`pareto`
    /// records; latest run wins per session id). Empty for scalar-only
    /// registries, whose files keep their pre-objective shape.
    pub pareto: Vec<ParetoRecord>,
    /// Record lines of types this build does not recognise but whose bodies
    /// parse as `key=value`; written back verbatim so a newer writer's
    /// records survive a snapshot by this build.
    pub extras: Vec<String>,
}

fn fmt_point(point: &[f64]) -> String {
    if point.is_empty() {
        "-".to_string()
    } else {
        point
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(",")
    }
}

fn parse_point(text: &str) -> Result<Vec<f64>, PatsmaError> {
    if text == "-" {
        return Ok(Vec::new());
    }
    text.split(',')
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| PatsmaError::registry(format!("bad point coord {v:?}")))
        })
        .collect()
}

impl ServiceReport {
    /// Total cache hits across the reported sessions.
    pub fn session_cache_hits(&self) -> u64 {
        self.sessions.iter().map(|s| s.cache_hits).sum()
    }

    /// Persisted state for a session id, if any.
    pub fn state_for(&self, id: &str) -> Option<&SessionState> {
        self.states.iter().find(|s| s.id == id)
    }

    /// Render as a markdown report (the `patsma service report` output).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "\n| session | workload | optimizer | warm | evals | target iters | cache hits | \
             best point | best cost | wall |\n|---|---|---|---|---|---|---|---|---|---|\n",
        );
        for s in &self.sessions {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {} | {} | {:.6e} | {} |\n",
                s.id,
                s.workload,
                s.optimizer,
                if s.warm_started { "yes" } else { "no" },
                s.evaluations,
                s.target_iterations,
                s.cache_hits,
                // Typed sessions show the decoded cell (categories by
                // name); numeric sessions the raw point.
                s.best_label
                    .clone()
                    .unwrap_or_else(|| fmt_point(&s.best_point)),
                s.best_cost,
                crate::bench::fmt_time(s.wall_secs),
            ));
        }
        let c = &self.cache;
        out.push_str(&format!(
            "\nsessions: {}; session cache hits: {}; shared cache: {} hits / {} misses \
             ({:.1}% hit rate), {} entries (cap {}, {} evicted); persisted states: {}\n",
            self.sessions.len(),
            self.session_cache_hits(),
            c.hits,
            c.misses,
            100.0 * c.hit_rate(),
            c.entries,
            c.cap,
            c.evictions,
            self.states.len(),
        ));
        if !self.pareto.is_empty() {
            out.push_str("\npareto fronts (non-dominated cells per session):\n");
            for p in &self.pareto {
                out.push_str(&format!(
                    "  {}: {} median={:.3e} p95={:.3e} eff={:.3e} scalar={:.3e}\n",
                    p.session,
                    p.label.clone().unwrap_or_else(|| fmt_point(&p.cell)),
                    p.median,
                    p.p95,
                    p.efficiency,
                    p.scalar,
                ));
            }
        }
        out
    }

    /// Serialise to the v2 registry format.
    pub fn to_text(&self) -> String {
        let mut out = format!("{HEADER_V2}\n");
        out.push_str(&format!(
            "cache hits={} misses={} entries={} evictions={} cap={}\n",
            self.cache.hits,
            self.cache.misses,
            self.cache.entries,
            self.cache.evictions,
            self.cache.cap
        ));
        for s in &self.sessions {
            let body = s
                .to_kv()
                .into_iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            out.push_str(&format!("session {body}\n"));
        }
        for st in &self.states {
            let body = st
                .to_kv()
                .into_iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" ");
            out.push_str(&format!("state {body}\n"));
        }
        for entry in &self.table {
            out.push_str(&entry.to_record());
            out.push('\n');
        }
        for p in &self.pareto {
            out.push_str(&p.to_record());
            out.push('\n');
        }
        for line in &self.extras {
            out.push_str(line);
            out.push('\n');
        }
        out
    }

    /// Parse a registry (v2 `key=value` or legacy v1 positional). Strict:
    /// malformed records are an error (use
    /// [`from_text_lenient`](Self::from_text_lenient) to recover instead);
    /// unknown *keys* inside a known record are ignored.
    pub fn from_text(text: &str) -> Result<Self, PatsmaError> {
        let (report, skipped) = Self::parse(text, false)?;
        debug_assert!(skipped.is_empty(), "strict parse cannot skip");
        Ok(report)
    }

    /// Parse, skipping malformed records instead of failing. Returns the
    /// recovered report and one human-readable note per skipped line. The
    /// header must still match — without it the file is not a registry and
    /// "recovering" it would fabricate an empty report from garbage.
    pub fn from_text_lenient(text: &str) -> Result<(Self, Vec<String>), PatsmaError> {
        Self::parse(text, true)
    }

    fn parse(text: &str, lenient: bool) -> Result<(Self, Vec<String>), PatsmaError> {
        let mut lines = text.lines();
        let version = match lines.next().map(str::trim) {
            Some(h) if h == HEADER_V2 => 2,
            Some(h) if h == HEADER_V1 => 1,
            other => {
                return Err(PatsmaError::registry(format!(
                    "not a service registry (header {other:?})"
                )))
            }
        };
        let mut cache = CacheStats {
            hits: 0,
            misses: 0,
            entries: 0,
            evictions: 0,
            cap: 0,
        };
        let mut sessions = Vec::new();
        let mut states = Vec::new();
        let mut table = Vec::new();
        let mut pareto = Vec::new();
        let mut extras = Vec::new();
        let mut skipped = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parsed = if version == 1 {
                parse_v1_record(line, &mut cache, &mut sessions)
            } else {
                parse_v2_record(
                    line,
                    &mut cache,
                    &mut sessions,
                    &mut states,
                    &mut table,
                    &mut pareto,
                    &mut extras,
                )
            };
            if let Err(e) = parsed {
                if lenient {
                    skipped.push(format!("line {}: {e}", lineno + 2));
                } else {
                    return Err(e.at_line(lineno + 2));
                }
            }
        }
        Ok((
            Self {
                sessions,
                states,
                cache,
                table,
                pareto,
                extras,
            },
            skipped,
        ))
    }

    /// Write the registry to `path`.
    pub fn save(&self, path: &Path) -> Result<(), PatsmaError> {
        std::fs::write(path, self.to_text())
            .map_err(|e| PatsmaError::io("writing registry", path, e))
    }

    /// Read a registry from `path` (strict).
    pub fn load(path: &Path) -> Result<Self, PatsmaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| PatsmaError::io("reading registry", path, e))?;
        Self::from_text(&text)
    }

    /// Read a registry from `path`, recovering what a corrupted file still
    /// holds; returns the skipped-line notes alongside.
    pub fn load_lenient(path: &Path) -> Result<(Self, Vec<String>), PatsmaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| PatsmaError::io("reading registry", path, e))?;
        Self::from_text_lenient(&text)
    }
}

/// Split a v2 record body into `(key, value)` pairs; values may themselves
/// contain `=` (descriptors), so only the first `=` per token splits.
pub(crate) fn split_kv(tokens: &[&str]) -> Result<Vec<(String, String)>, PatsmaError> {
    tokens
        .iter()
        .map(|t| {
            t.split_once('=')
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .ok_or_else(|| PatsmaError::registry(format!("token {t:?} is not key=value")))
        })
        .collect()
}

pub(crate) fn kv_get<'a>(pairs: &'a [(String, String)], key: &str) -> Result<&'a str, PatsmaError> {
    kv_opt(pairs, key).ok_or_else(|| PatsmaError::registry(format!("missing {key:?}")))
}

pub(crate) fn kv_opt<'a>(pairs: &'a [(String, String)], key: &str) -> Option<&'a str> {
    pairs
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
}

/// A required `key=value` whose value must parse as `T`.
pub(crate) fn kv_num<T: std::str::FromStr>(
    pairs: &[(String, String)],
    key: &str,
) -> Result<T, PatsmaError> {
    let v = kv_get(pairs, key)?;
    v.parse()
        .map_err(|_| PatsmaError::registry(format!("bad {key} {v:?}")))
}

/// An optional `key=value` whose value, when present, must parse as `T`;
/// absent keys yield `default` (back-compat with older writers).
pub(crate) fn kv_num_or<T: std::str::FromStr>(
    pairs: &[(String, String)],
    key: &str,
    default: T,
) -> Result<T, PatsmaError> {
    match kv_opt(pairs, key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| PatsmaError::registry(format!("bad {key} {v:?}"))),
    }
}

fn parse_v2_record(
    line: &str,
    cache: &mut CacheStats,
    sessions: &mut Vec<SessionReport>,
    states: &mut Vec<SessionState>,
    table: &mut Vec<TableEntry>,
    pareto: &mut Vec<ParetoRecord>,
    extras: &mut Vec<String>,
) -> Result<(), PatsmaError> {
    let tokens: Vec<&str> = line.split_whitespace().collect();
    let pairs = split_kv(&tokens[1..])?;
    match tokens[0] {
        "cache" => {
            *cache = CacheStats {
                hits: kv_num(&pairs, "hits")?,
                misses: kv_num(&pairs, "misses")?,
                entries: kv_num(&pairs, "entries")?,
                // Pre-LRU v2 writers did not emit these; zero is honest.
                evictions: kv_num_or(&pairs, "evictions", 0)?,
                cap: kv_num_or(&pairs, "cap", 0)?,
            };
        }
        "session" => {
            sessions.push(SessionReport::from_kv(&pairs)?);
        }
        "state" => {
            let borrowed: Vec<(&str, &str)> = pairs
                .iter()
                .map(|(k, v)| (k.as_str(), v.as_str()))
                .collect();
            states.push(SessionState::from_kv(&borrowed)?);
        }
        "table" => {
            table.push(TableEntry::from_kv(&pairs)?);
        }
        "pareto" => {
            pareto.push(ParetoRecord::from_kv(&pairs)?);
        }
        // A record type from a newer writer. The body already parsed as
        // key=value above (binary junk still errors), so carry the line
        // verbatim: it survives this build's next snapshot.
        _ => extras.push(line.to_string()),
    }
    Ok(())
}

/// The original positional format: `cache H M E` and 11-field `session`
/// lines. Loaded for back-compat; saving always writes v2.
fn parse_v1_record(
    line: &str,
    cache: &mut CacheStats,
    sessions: &mut Vec<SessionReport>,
) -> Result<(), PatsmaError> {
    let num = |v: &str, what: &str| -> Result<u64, PatsmaError> {
        v.parse()
            .map_err(|_| PatsmaError::registry(format!("bad {what} {v:?}")))
    };
    let float = |v: &str, what: &str| -> Result<f64, PatsmaError> {
        v.parse()
            .map_err(|_| PatsmaError::registry(format!("bad {what} {v:?}")))
    };
    let f: Vec<&str> = line.split_whitespace().collect();
    match f[0] {
        "cache" if f.len() == 4 => {
            *cache = CacheStats {
                hits: num(f[1], "hits")?,
                misses: num(f[2], "misses")?,
                entries: num(f[3], "entries")? as usize,
                evictions: 0,
                cap: 0,
            };
        }
        "session" if f.len() == 11 => {
            sessions.push(SessionReport {
                id: f[1].to_string(),
                workload: f[2].to_string(),
                optimizer: f[3].to_string(),
                evaluations: num(f[4], "evaluations")?,
                target_iterations: num(f[5], "iters")?,
                cache_hits: num(f[6], "cache hits")?,
                cache_misses: num(f[7], "cache misses")?,
                best_point: parse_point(f[8])?,
                best_label: None,
                best_cost: float(f[9], "best cost")?,
                wall_secs: float(f[10], "wall seconds")?,
                warm_started: false,
                extra: Vec::new(),
            });
        }
        _ => return Err(PatsmaError::registry(format!("unrecognised record {line:?}"))),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adaptive::table::{ContextKey, TunedCell};
    use crate::optimizer::OptimizerState;
    use crate::service::state::EnvFingerprint;

    fn sample_state(id: &str) -> SessionState {
        SessionState {
            id: id.into(),
            workload: "synthetic/opt=48/dim=1/lo=1/hi=128/kind=int".into(),
            fingerprint: 123_456,
            env: EnvFingerprint::with_threads(8),
            optimizer: "csa".into(),
            num_opt: 4,
            max_iter: 8,
            seed: 42,
            ignore: 0,
            best_point: vec![47.0],
            best_cost: 1.0104,
            opt_state: OptimizerState {
                optimizer: "csa".into(),
                best_internal: vec![-0.28],
                best_cost: 1.0104,
                temperatures: Some((0.125, 1.75)),
                points: vec![vec![-0.28], vec![0.5]],
            },
            extra: Vec::new(),
        }
    }

    fn sample() -> ServiceReport {
        ServiceReport {
            sessions: vec![
                SessionReport {
                    id: "s0".into(),
                    workload: "synthetic/best=48/dim=1".into(),
                    optimizer: "csa".into(),
                    evaluations: 20,
                    target_iterations: 17,
                    cache_hits: 3,
                    cache_misses: 17,
                    best_point: vec![47.0],
                    best_label: None,
                    best_cost: 1.0104,
                    wall_secs: 0.002,
                    warm_started: false,
                    extra: Vec::new(),
                },
                SessionReport {
                    id: "s1".into(),
                    workload: "synthetic/best=24/dim=2".into(),
                    optimizer: "nelder-mead".into(),
                    evaluations: 12,
                    target_iterations: 12,
                    cache_hits: 0,
                    cache_misses: 12,
                    best_point: vec![25.5, 23.0],
                    best_label: Some("dynamic,23".into()),
                    best_cost: 2.1,
                    wall_secs: 0.001,
                    warm_started: true,
                    extra: Vec::new(),
                },
            ],
            states: vec![sample_state("s0")],
            cache: CacheStats {
                hits: 3,
                misses: 29,
                entries: 29,
                evictions: 4,
                cap: 65_536,
            },
            table: vec![TableEntry {
                key: ContextKey {
                    workload: 0xBEEF,
                    bucket: 20,
                    threads: 8,
                    env: 0xD00D,
                    objective: 0,
                },
                cell: TunedCell {
                    point: vec![48.0, 0.25],
                    cost: 0.001_953_125,
                    weight: 5,
                    label: Some("dynamic,chunk=48".into()),
                },
            }],
            pareto: vec![
                ParetoRecord {
                    session: "s1".into(),
                    cell: vec![2.0, 23.0],
                    label: Some("dynamic,23".into()),
                    median: 0.002,
                    p95: 0.0025,
                    efficiency: 50.0,
                    scalar: 0.007,
                },
                ParetoRecord {
                    session: "s1".into(),
                    cell: vec![0.0, 64.0],
                    label: None,
                    median: 0.003,
                    p95: 0.0031,
                    efficiency: 80.645,
                    scalar: 0.0092,
                },
            ],
            extras: Vec::new(),
        }
    }

    #[test]
    fn text_roundtrip_is_lossless() {
        let r = sample();
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn save_load_roundtrip() {
        let r = sample();
        let path = std::env::temp_dir().join("patsma-registry-test.txt");
        r.save(&path).unwrap();
        let loaded = ServiceReport::load(&path).unwrap();
        assert_eq!(loaded, r);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_keys_are_preserved_forward_compat() {
        // A future writer adds fields; this reader must not choke on them,
        // and must not destroy them when it snapshots the registry back out.
        let mut text = String::from(
            "# patsma-service-registry v2\n\
             cache hits=1 misses=2 entries=2 compression=zstd\n",
        );
        text.push_str(
            "session id=s9 workload=w optimizer=csa evals=4 iters=4 hits=0 misses=4 \
             best=3 cost=0.5 wall=0.01 warm=0 gpu_time=0.3 battery=full\n",
        );
        let r = ServiceReport::from_text(&text).unwrap();
        assert_eq!(r.sessions.len(), 1);
        assert_eq!(r.sessions[0].id, "s9");
        assert_eq!(
            r.sessions[0].extra,
            vec![
                ("gpu_time".to_string(), "0.3".to_string()),
                ("battery".to_string(), "full".to_string()),
            ]
        );
        assert_eq!(r.cache.misses, 2);
        // A pre-LRU cache record: evictions/cap default to zero.
        assert_eq!((r.cache.evictions, r.cache.cap), (0, 0));
    }

    #[test]
    fn load_snapshot_roundtrip_preserves_foreign_records_and_keys() {
        // The satellite regression: a lenient load used to drop everything
        // it did not understand, so the first snapshot by an older build
        // silently destroyed a newer writer's records. Both unknown keys in
        // known records and whole unknown record types must survive a
        // load → to_text → load cycle.
        let text = "# patsma-service-registry v2\n\
                    cache hits=0 misses=1 entries=1 evictions=0 cap=16\n\
                    session id=s0 workload=w optimizer=csa evals=2 iters=2 hits=0 misses=2 \
                    best=7 cost=0.5 wall=0.01 warm=0 gpu_time=0.3\n\
                    table workload=7 bucket=12 threads=4 env=9 point=32 cost=0.25 weight=3\n\
                    telemetry format=v3 samples=128\n";
        let first = ServiceReport::from_text(text).unwrap();
        assert_eq!(first.table.len(), 1);
        assert_eq!(first.table[0].cell.point, vec![32.0]);
        assert_eq!(
            first.extras,
            vec!["telemetry format=v3 samples=128".to_string()]
        );
        let rewritten = first.to_text();
        assert!(rewritten.contains("gpu_time=0.3"), "{rewritten}");
        assert!(rewritten.contains("telemetry format=v3 samples=128"), "{rewritten}");
        assert!(rewritten.contains("table "), "{rewritten}");
        let second = ServiceReport::from_text(&rewritten).unwrap();
        assert_eq!(second, first);
    }

    #[test]
    fn missing_warm_key_defaults_to_cold() {
        let text = "# patsma-service-registry v2\n\
                    session id=s0 workload=w optimizer=csa evals=1 iters=1 hits=0 misses=1 \
                    best=2 cost=0.1 wall=0.01\n";
        let r = ServiceReport::from_text(text).unwrap();
        assert!(!r.sessions[0].warm_started);
    }

    #[test]
    fn v1_files_still_load() {
        let text = "# patsma-service-registry v1\n\
                    cache 3 29 29\n\
                    session s0 synthetic/best=48/dim=1 csa 20 17 3 17 47 1.0104 0.002\n";
        let r = ServiceReport::from_text(text).unwrap();
        assert_eq!(r.sessions.len(), 1);
        assert_eq!(r.sessions[0].best_point, vec![47.0]);
        assert_eq!(r.cache.hits, 3);
        assert!(r.states.is_empty());
        assert!(!r.sessions[0].warm_started);
        assert_eq!(
            r.sessions[0].best_label, None,
            "old numeric records have no typed label"
        );
    }

    #[test]
    fn session_kv_codec_roundtrips() {
        // The wire protocol reuses to_kv/from_kv verbatim; pin the codec
        // independently of the file framing.
        for s in sample().sessions {
            let parsed = SessionReport::from_kv(&s.to_kv()).unwrap();
            assert_eq!(parsed, s);
        }
    }

    #[test]
    fn typed_labels_roundtrip_and_render() {
        // The text roundtrip already covers Some/None (sample has both);
        // check the rendered table prefers the typed cell.
        let r = sample();
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed.sessions[0].best_label, None);
        assert_eq!(parsed.sessions[1].best_label, Some("dynamic,23".into()));
        let table = r.render();
        assert!(table.contains("| dynamic,23 |"), "{table}");
        // Records without the label key (pre-joint writers) still load.
        let text = "# patsma-service-registry v2\n\
                    session id=old workload=w optimizer=csa evals=1 iters=1 hits=0 misses=1 \
                    best=2 cost=0.1 wall=0.01 warm=0\n";
        let old = ServiceReport::from_text(text).unwrap();
        assert_eq!(old.sessions[0].best_label, None);
    }

    #[test]
    fn lenient_parse_recovers_around_corruption() {
        let good = sample();
        let mut text = good.to_text();
        // Corrupt the middle: a truncated record and binary junk.
        text.push_str("session id=broken workload=w optimizer=csa evals=NOTANUMBER\n");
        text.push_str("\u{0}\u{1}garbage record here\n");
        text.push_str(
            "session id=tail workload=w optimizer=sa evals=2 iters=2 hits=0 misses=2 \
             best=5 cost=0.25 wall=0.001 warm=0\n",
        );
        // Strict parse refuses...
        assert!(ServiceReport::from_text(&text).is_err());
        // ...lenient parse keeps everything salvageable.
        let (r, skipped) = ServiceReport::from_text_lenient(&text).unwrap();
        assert_eq!(skipped.len(), 2, "{skipped:?}");
        assert_eq!(r.sessions.len(), good.sessions.len() + 1);
        assert_eq!(r.sessions.last().unwrap().id, "tail");
        assert_eq!(r.states.len(), 1);
    }

    #[test]
    fn lenient_parse_still_requires_the_header() {
        assert!(ServiceReport::from_text_lenient("random junk\nmore junk\n").is_err());
    }

    #[test]
    fn strict_errors_carry_the_line_number() {
        let text = "# patsma-service-registry v2\n\
                    cache hits=1 misses=2 entries=2\n\
                    session id=bad workload=w optimizer=csa evals=NaNsense\n";
        let err = ServiceReport::from_text(text).unwrap_err();
        assert!(
            matches!(err, PatsmaError::Registry { line: Some(3), .. }),
            "{err}"
        );
        assert!(err.to_string().contains("line 3"), "{err}");
    }

    #[test]
    fn pareto_records_roundtrip_and_torn_lines_are_typed_errors() {
        // The codec itself (label present and absent) rides through
        // text_roundtrip_is_lossless via sample(); pin the failure shape of
        // torn lines here: strict parse fails with the line number, lenient
        // parse skips the torn record and keeps the intact one.
        let text = "# patsma-service-registry v2\n\
                    cache hits=0 misses=0 entries=0 evictions=0 cap=16\n\
                    pareto id=s1 cell=2,23 median=0.002 p95=0.0025 eff=50 scalar=0.007\n\
                    pareto id=s1 cell=0,64 median=NOTANUMBER p95=0.0031\n";
        let err = ServiceReport::from_text(text).unwrap_err();
        assert!(
            matches!(err, PatsmaError::Registry { line: Some(4), .. }),
            "{err}"
        );
        let (r, skipped) = ServiceReport::from_text_lenient(text).unwrap();
        assert_eq!(skipped.len(), 1, "{skipped:?}");
        assert_eq!(r.pareto.len(), 1);
        assert_eq!(r.pareto[0].cell, vec![2.0, 23.0]);
        assert_eq!(r.pareto[0].label, None);
        // A truncated record missing required keys is also typed, never a
        // panic.
        let torn = "# patsma-service-registry v2\n\
                    pareto id=s1\n";
        assert!(matches!(
            ServiceReport::from_text(torn).unwrap_err(),
            PatsmaError::Registry { .. }
        ));
    }

    #[test]
    fn render_lists_pareto_fronts() {
        let text = sample().render();
        assert!(text.contains("pareto fronts"), "{text}");
        assert!(text.contains("s1: dynamic,23"), "{text}");
        // The unlabeled cell falls back to its coordinates.
        assert!(text.contains("s1: 0,64"), "{text}");
    }

    #[test]
    fn render_reports_cache_hits_and_states() {
        let text = sample().render();
        assert!(text.contains("cache hits"), "{text}");
        assert!(text.contains("session cache hits: 3"), "{text}");
        assert!(text.contains("| s0 |"), "{text}");
        assert!(text.contains("persisted states: 1"), "{text}");
        assert!(text.contains("| yes |"), "{text}");
        // The LRU bound is operator-visible (satellite: cap + evict counts).
        assert!(text.contains("cap 65536"), "{text}");
        assert!(text.contains("4 evicted"), "{text}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ServiceReport::from_text("nonsense").is_err());
        assert!(
            ServiceReport::from_text("# patsma-service-registry v2\nbogus line here").is_err()
        );
    }

    #[test]
    fn float_best_points_roundtrip_exactly() {
        let mut r = sample();
        r.sessions[0].best_point = vec![32.248_737_510_186_3, 0.125];
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed.sessions[0].best_point, r.sessions[0].best_point);
    }
}
