//! Results registry — what the service hands the coordinator and CLI.
//!
//! Each completed session yields a [`SessionReport`]; a batch run yields a
//! [`ServiceReport`] (sessions + a cache-counter snapshot). The registry
//! serialises to a plain whitespace-separated text file (the offline build
//! has no serde) so `patsma service report` can render results from an
//! earlier `patsma service run` process.

use super::cache::CacheStats;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Magic first line of a registry file (format version gate).
const HEADER: &str = "# patsma-service-registry v1";

/// One finished tuning session.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionReport {
    /// Caller-chosen session label (no whitespace).
    pub id: String,
    /// Workload descriptor (the fingerprint input; no whitespace).
    pub workload: String,
    /// Optimizer name.
    pub optimizer: String,
    /// Optimizer evaluations consumed (cache hits included — the optimizer
    /// cannot tell a cached cost from a fresh one).
    pub evaluations: u64,
    /// Target iterations actually executed (cache hits excluded — that is
    /// the point of the cache).
    pub target_iterations: u64,
    /// Batch evaluations answered from the shared cache.
    pub cache_hits: u64,
    /// Batch evaluations that ran the target.
    pub cache_misses: u64,
    /// Best measured point (user domain, quantised).
    pub best_point: Vec<i64>,
    /// Best measured cost.
    pub best_cost: f64,
    /// Session wall-clock seconds.
    pub wall_secs: f64,
}

/// A batch of session results plus the shared-cache counters.
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceReport {
    /// Per-session results, spec order.
    pub sessions: Vec<SessionReport>,
    /// Cache counters at the end of the batch.
    pub cache: CacheStats,
}

impl ServiceReport {
    /// Total cache hits across the reported sessions.
    pub fn session_cache_hits(&self) -> u64 {
        self.sessions.iter().map(|s| s.cache_hits).sum()
    }

    /// Render as a markdown report (the `patsma service report` output).
    pub fn render(&self) -> String {
        let mut out = String::from(
            "\n| session | workload | optimizer | evals | target iters | cache hits | \
             best point | best cost | wall |\n|---|---|---|---|---|---|---|---|---|\n",
        );
        for s in &self.sessions {
            out.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} | {:?} | {:.6e} | {} |\n",
                s.id,
                s.workload,
                s.optimizer,
                s.evaluations,
                s.target_iterations,
                s.cache_hits,
                s.best_point,
                s.best_cost,
                crate::benchkit::fmt_time(s.wall_secs),
            ));
        }
        let c = &self.cache;
        out.push_str(&format!(
            "\nsessions: {}; session cache hits: {}; shared cache: {} hits / {} misses \
             ({:.1}% hit rate), {} entries\n",
            self.sessions.len(),
            self.session_cache_hits(),
            c.hits,
            c.misses,
            100.0 * c.hit_rate(),
            c.entries,
        ));
        out
    }

    /// Serialise to the plain-text registry format.
    pub fn to_text(&self) -> String {
        let mut out = format!("{HEADER}\n");
        out.push_str(&format!(
            "cache {} {} {}\n",
            self.cache.hits, self.cache.misses, self.cache.entries
        ));
        for s in &self.sessions {
            let point = s
                .best_point
                .iter()
                .map(|v| v.to_string())
                .collect::<Vec<_>>()
                .join(",");
            out.push_str(&format!(
                "session {} {} {} {} {} {} {} {} {} {}\n",
                s.id,
                s.workload,
                s.optimizer,
                s.evaluations,
                s.target_iterations,
                s.cache_hits,
                s.cache_misses,
                point,
                s.best_cost,
                s.wall_secs,
            ));
        }
        out
    }

    /// Parse the plain-text registry format.
    pub fn from_text(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        match lines.next() {
            Some(h) if h.trim() == HEADER => {}
            other => bail!("not a service registry (header {other:?})"),
        }
        let mut cache = CacheStats {
            hits: 0,
            misses: 0,
            entries: 0,
        };
        let mut sessions = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            let ctx = |what: &str| format!("registry line {}: bad {what}", lineno + 2);
            match f[0] {
                "cache" if f.len() == 4 => {
                    cache = CacheStats {
                        hits: f[1].parse().with_context(|| ctx("hits"))?,
                        misses: f[2].parse().with_context(|| ctx("misses"))?,
                        entries: f[3].parse().with_context(|| ctx("entries"))?,
                    };
                }
                "session" if f.len() == 11 => {
                    let best_point = f[8]
                        .split(',')
                        .map(|v| v.parse::<i64>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .with_context(|| ctx("best point"))?;
                    sessions.push(SessionReport {
                        id: f[1].to_string(),
                        workload: f[2].to_string(),
                        optimizer: f[3].to_string(),
                        evaluations: f[4].parse().with_context(|| ctx("evaluations"))?,
                        target_iterations: f[5].parse().with_context(|| ctx("iters"))?,
                        cache_hits: f[6].parse().with_context(|| ctx("cache hits"))?,
                        cache_misses: f[7].parse().with_context(|| ctx("cache misses"))?,
                        best_point,
                        best_cost: f[9].parse().with_context(|| ctx("best cost"))?,
                        wall_secs: f[10].parse().with_context(|| ctx("wall seconds"))?,
                    });
                }
                _ => bail!("registry line {}: unrecognised record {line:?}", lineno + 2),
            }
        }
        Ok(Self { sessions, cache })
    }

    /// Write the registry to `path`.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("writing registry {}", path.display()))
    }

    /// Read a registry from `path`.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading registry {}", path.display()))?;
        Self::from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ServiceReport {
        ServiceReport {
            sessions: vec![
                SessionReport {
                    id: "s0".into(),
                    workload: "synthetic/best=48/dim=1".into(),
                    optimizer: "csa".into(),
                    evaluations: 20,
                    target_iterations: 17,
                    cache_hits: 3,
                    cache_misses: 17,
                    best_point: vec![47],
                    best_cost: 1.0104,
                    wall_secs: 0.002,
                },
                SessionReport {
                    id: "s1".into(),
                    workload: "synthetic/best=24/dim=2".into(),
                    optimizer: "nelder-mead".into(),
                    evaluations: 12,
                    target_iterations: 12,
                    cache_hits: 0,
                    cache_misses: 12,
                    best_point: vec![25, 23],
                    best_cost: 2.1,
                    wall_secs: 0.001,
                },
            ],
            cache: CacheStats {
                hits: 3,
                misses: 29,
                entries: 29,
            },
        }
    }

    #[test]
    fn text_roundtrip_is_lossless() {
        let r = sample();
        let parsed = ServiceReport::from_text(&r.to_text()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn save_load_roundtrip() {
        let r = sample();
        let path = std::env::temp_dir().join("patsma-registry-test.txt");
        r.save(&path).unwrap();
        let loaded = ServiceReport::load(&path).unwrap();
        assert_eq!(loaded, r);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn render_reports_cache_hits() {
        let text = sample().render();
        assert!(text.contains("cache hits"), "{text}");
        assert!(text.contains("session cache hits: 3"), "{text}");
        assert!(text.contains("| s0 |"), "{text}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(ServiceReport::from_text("nonsense").is_err());
        assert!(
            ServiceReport::from_text("# patsma-service-registry v1\nbogus line here").is_err()
        );
    }
}
