//! The tuning daemon — a persistent process serving the wire protocol.
//!
//! `patsma daemon start` promotes the in-process [`TuningService`] to a
//! long-lived server: clients connect to a unix socket, exchange
//! length-prefixed [`proto`] frames, and every request routes through the
//! *same* [`TuningService::handle`] API an in-process caller uses — the
//! daemon adds exactly three things on top:
//!
//! 1. **The socket** — an accept loop spawning one handler thread per
//!    connection ([`DaemonClient`] is the typed client side);
//! 2. **Persistence** — a background thread periodically compacts the
//!    session history and atomically snapshots the compacted registry
//!    (write-to-temp + rename), and the daemon seeds itself from the
//!    registry on startup (leniently: corrupt records are skipped, not
//!    fatal);
//! 3. **Graceful drain** — on SIGTERM/SIGINT (or a `shutdown` request) the
//!    daemon stops accepting connections, lets in-flight sessions finish,
//!    answers idle clients with a clean `draining` frame, writes a final
//!    snapshot, and removes the socket. No converged session is lost.
//!
//! ```text
//!             ┌────────────────────────── patsma daemon ─┐
//! client ──┐  │ accept loop ─▶ handler threads ─▶ handle()│
//! client ──┼──▶   (socket)         │                 │    │
//! client ──┘  │                    ▼                 ▼    │
//!             │              proto frames    ShardedSessions + PointCache
//!             │ snapshot thread ─▶ compact + atomic registry snapshot
//!             └──────────────────────────────────────────┘
//! ```

use super::proto::{self, Request, Response};
use super::registry::ServiceReport;
use super::{SessionReport, SessionSpec, TuningService};
use crate::adaptive::table::{ContextKey, TableEntry};
use crate::error::PatsmaError;
use std::io::ErrorKind;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

/// SIGTERM/SIGINT routing without a libc dependency: the C `signal`
/// function with a handler that does nothing but one atomic store (the
/// only async-signal-safe thing worth doing).
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TERM: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_signum: i32) {
        TERM.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    /// Route SIGTERM and SIGINT to the termination flag. Idempotent;
    /// installing again is harmless.
    pub(super) fn install() {
        unsafe {
            signal(SIGTERM, on_signal as usize);
            signal(SIGINT, on_signal as usize);
        }
    }

    /// Whether a termination signal has arrived.
    pub(super) fn requested() -> bool {
        TERM.load(Ordering::SeqCst)
    }
}

/// How a daemon is configured (what `patsma daemon start` flags build).
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Unix socket path the daemon listens on.
    pub socket: PathBuf,
    /// Registry file snapshots are written to (and seeded from on start).
    pub registry: PathBuf,
    /// Concurrent session bound (the service's thread pool).
    pub concurrency: usize,
    /// Session-map shard count.
    pub shards: usize,
    /// Point-cache residency cap (entries).
    pub cache_cap: usize,
    /// How often the background thread compacts and snapshots.
    pub snapshot_interval: Duration,
}

impl DaemonConfig {
    /// A config with the default concurrency (4), shard count, cache cap
    /// and a 30-second snapshot interval.
    pub fn new(socket: impl Into<PathBuf>, registry: impl Into<PathBuf>) -> Self {
        Self {
            socket: socket.into(),
            registry: registry.into(),
            concurrency: 4,
            shards: super::DEFAULT_SHARDS,
            cache_cap: super::DEFAULT_CACHE_CAP,
            snapshot_interval: Duration::from_secs(30),
        }
    }

    /// Builder-style concurrency override.
    pub fn with_concurrency(mut self, concurrency: usize) -> Self {
        self.concurrency = concurrency;
        self
    }

    /// Builder-style shard-count override.
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Builder-style cache-cap override.
    pub fn with_cache_cap(mut self, cache_cap: usize) -> Self {
        self.cache_cap = cache_cap;
        self
    }

    /// Builder-style snapshot-interval override.
    pub fn with_snapshot_interval(mut self, interval: Duration) -> Self {
        self.snapshot_interval = interval;
        self
    }
}

/// What a drained daemon reports back (the `daemon start` exit summary).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DrainSummary {
    /// Requests served over the daemon's lifetime.
    pub requests: u64,
    /// Sessions held at shutdown (all persisted in the final snapshot).
    pub sessions: usize,
    /// Registry snapshots written (the final one included).
    pub snapshots: u64,
    /// History entries dropped by compaction.
    pub compacted: u64,
}

/// State shared between the accept loop, handler threads, the snapshot
/// thread and the [`DaemonHandle`].
struct DaemonShared {
    service: TuningService,
    config: DaemonConfig,
    drain: AtomicBool,
    requests: AtomicU64,
    snapshots: AtomicU64,
    compacted: AtomicU64,
}

impl DaemonShared {
    /// Drain comes from three places: [`DaemonHandle::begin_drain`], a
    /// termination signal, or a `shutdown` request (which drains the
    /// service directly).
    fn drain_requested(&self) -> bool {
        self.drain.load(Ordering::SeqCst) || sig::requested() || self.service.is_draining()
    }

    /// Atomically publish the compacted registry: write a temp file next
    /// to the target, then rename over it — a concurrent `service report
    /// --registry` reader never sees a half-written file.
    fn snapshot(&self) -> Result<(), PatsmaError> {
        let report = self.service.registry_snapshot();
        let tmp = self.config.registry.with_extension("tmp");
        std::fs::write(&tmp, report.to_text())
            .map_err(|e| PatsmaError::io("writing registry snapshot", &tmp, e))?;
        std::fs::rename(&tmp, &self.config.registry)
            .map_err(|e| PatsmaError::io("publishing registry snapshot", &self.config.registry, e))?;
        self.snapshots.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn compact(&self) {
        let dropped = self.service.compact_history() as u64;
        self.compacted.fetch_add(dropped, Ordering::Relaxed);
    }
}

/// A running daemon (returned by [`spawn`]). Dropping the handle leaves
/// the daemon running detached; [`wait`](Self::wait) blocks until drain.
pub struct DaemonHandle {
    shared: Arc<DaemonShared>,
    accept: Option<JoinHandle<()>>,
    snapshotter: Option<JoinHandle<()>>,
}

impl DaemonHandle {
    /// The socket the daemon listens on.
    pub fn socket(&self) -> &Path {
        &self.shared.config.socket
    }

    /// The registry file the daemon snapshots to.
    pub fn registry(&self) -> &Path {
        &self.shared.config.registry
    }

    /// Begin a graceful drain (equivalent to sending SIGTERM): stop
    /// accepting, let in-flight sessions finish, refuse new ones.
    pub fn begin_drain(&self) {
        self.shared.drain.store(true, Ordering::SeqCst);
        self.shared.service.begin_drain();
    }

    /// Block until the daemon has drained (SIGTERM, `shutdown` request or
    /// [`begin_drain`](Self::begin_drain)), write the final snapshot,
    /// remove the socket and report lifetime counters.
    pub fn wait(mut self) -> Result<DrainSummary, PatsmaError> {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.snapshotter.take() {
            let _ = h.join();
        }
        // All in-flight sessions have finished; persist exactly what the
        // service converged on.
        self.shared.compact();
        self.shared.snapshot()?;
        let _ = std::fs::remove_file(&self.shared.config.socket);
        Ok(DrainSummary {
            requests: self.shared.requests.load(Ordering::Relaxed),
            sessions: self.shared.service.registry_snapshot().sessions.len(),
            snapshots: self.shared.snapshots.load(Ordering::Relaxed),
            compacted: self.shared.compacted.load(Ordering::Relaxed),
        })
    }
}

/// Start a daemon: bind the socket, seed the service from the registry
/// (leniently — a corrupt record costs that record, not the daemon), and
/// spawn the accept + snapshot threads. Refuses to start when another
/// daemon is already answering on the socket.
pub fn spawn(config: DaemonConfig) -> Result<DaemonHandle, PatsmaError> {
    if UnixStream::connect(&config.socket).is_ok() {
        return Err(PatsmaError::Invalid(format!(
            "daemon already listening on {}",
            config.socket.display()
        )));
    }
    if config.socket.exists() {
        // A stale socket file from a killed daemon; bind would fail on it.
        std::fs::remove_file(&config.socket)
            .map_err(|e| PatsmaError::io("removing stale socket", &config.socket, e))?;
    }
    let service = TuningService::with_options(config.concurrency, config.shards, config.cache_cap);
    if config.registry.exists() {
        let (loaded, _skipped) = ServiceReport::load_lenient(&config.registry)?;
        service.seed_from(&loaded);
    }
    let listener = UnixListener::bind(&config.socket)
        .map_err(|e| PatsmaError::io("binding daemon socket", &config.socket, e))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| PatsmaError::io("configuring daemon socket", &config.socket, e))?;
    sig::install();
    let shared = Arc::new(DaemonShared {
        service,
        config,
        drain: AtomicBool::new(false),
        requests: AtomicU64::new(0),
        snapshots: AtomicU64::new(0),
        compacted: AtomicU64::new(0),
    });
    let accept = {
        let shared = Arc::clone(&shared);
        thread::Builder::new()
            .name("patsma-daemon-accept".into())
            .spawn(move || accept_loop(&listener, &shared))
            .map_err(|e| PatsmaError::Invalid(format!("spawning accept thread: {e}")))?
    };
    let snapshotter = {
        let shared = Arc::clone(&shared);
        thread::Builder::new()
            .name("patsma-daemon-snapshot".into())
            .spawn(move || snapshot_loop(&shared))
            .map_err(|e| PatsmaError::Invalid(format!("spawning snapshot thread: {e}")))?
    };
    Ok(DaemonHandle {
        shared,
        accept: Some(accept),
        snapshotter: Some(snapshotter),
    })
}

/// Accept connections until drain, then join every handler — in-flight
/// requests (tuning runs included) finish before the daemon exits.
fn accept_loop(listener: &UnixListener, shared: &Arc<DaemonShared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if shared.drain_requested() {
            break;
        }
        match listener.accept() {
            Ok((stream, _addr)) => {
                let shared = Arc::clone(shared);
                if let Ok(h) = thread::Builder::new()
                    .name("patsma-daemon-conn".into())
                    .spawn(move || serve_connection(stream, &shared))
                {
                    handlers.push(h);
                }
                handlers.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            // A broken listener cannot accept anyone; drain what's left.
            Err(_) => break,
        }
    }
    // Queued-but-unhandled requests must see the drain, not start work.
    shared.service.begin_drain();
    for h in handlers {
        let _ = h.join();
    }
}

/// Periodic compaction + snapshot, in small ticks so drain is prompt.
fn snapshot_loop(shared: &Arc<DaemonShared>) {
    let tick = Duration::from_millis(50);
    let mut elapsed = Duration::ZERO;
    loop {
        if shared.drain_requested() {
            return;
        }
        thread::sleep(tick);
        elapsed += tick;
        if elapsed >= shared.config.snapshot_interval {
            elapsed = Duration::ZERO;
            shared.compact();
            // A failed snapshot (disk full, registry dir gone) must not
            // kill the daemon; the next interval retries.
            let _ = shared.snapshot();
        }
    }
}

/// How many *stalled* read timeouts a client may spend mid-frame before
/// the connection is dropped — bounds how long a half-sent request can
/// hold up a drain. Timeouts where the frame made progress reset the
/// clock: a slow-but-moving writer is resumed indefinitely.
const MID_FRAME_PATIENCE: u32 = 200; // × the 50 ms read timeout = 10 s

/// After pushing the unsolicited `draining` frame, how many more idle
/// read timeouts to linger before closing — long enough that a request
/// already in flight gets a `draining` answer instead of a broken pipe.
const DRAIN_LINGER: u32 = 10; // × the 50 ms read timeout = 0.5 s

/// One connection's request/response loop. Every parsed request routes
/// through [`TuningService::handle`]; a drain while the client is idle
/// gets a clean `draining` frame before the close.
///
/// The [`proto::FrameReader`] persists across read timeouts, so a client
/// writing a frame slower than the 50 ms timeout is *resumed* mid-frame
/// rather than having its request dropped (ISSUE 9 bugfix); only a client
/// making no progress at all runs down [`MID_FRAME_PATIENCE`].
fn serve_connection(mut stream: UnixStream, shared: &Arc<DaemonShared>) {
    // Accepted sockets are blocking; short read timeouts let the handler
    // notice a drain between requests instead of blocking forever.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut reader = proto::FrameReader::new();
    let mut linger = 0u32;
    let mut stalls = 0u32;
    let mut last_progress = 0usize;
    loop {
        match reader.step(&mut stream) {
            Ok(proto::FrameStep::Closed) | Err(_) => return,
            Ok(proto::FrameStep::Pending) => {
                if reader.mid_frame() {
                    if reader.progress() == last_progress {
                        stalls += 1;
                        if stalls > MID_FRAME_PATIENCE {
                            return;
                        }
                    } else {
                        last_progress = reader.progress();
                        stalls = 0;
                    }
                } else if shared.drain_requested() {
                    if linger == 0
                        && proto::write_frame(&mut stream, &Response::Draining.to_wire())
                            .is_err()
                    {
                        return;
                    }
                    linger += 1;
                    if linger > DRAIN_LINGER {
                        return;
                    }
                }
            }
            Ok(proto::FrameStep::Frame(record)) => {
                stalls = 0;
                last_progress = 0;
                shared.requests.fetch_add(1, Ordering::Relaxed);
                let response = match Request::from_wire(&record) {
                    Ok(request) => shared.service.handle(request),
                    Err(e) => Response::Error(e.to_string()),
                };
                if proto::write_frame(&mut stream, &response.to_wire()).is_err() {
                    return;
                }
            }
        }
    }
}

/// Typed client for a running daemon — the same [`Request`]/[`Response`]
/// API, spoken over the socket.
///
/// One client holds one connection; requests on it are sequential (send,
/// then block on the answer). Concurrency comes from multiple clients.
pub struct DaemonClient {
    stream: UnixStream,
}

impl DaemonClient {
    /// Connect to a daemon's socket.
    pub fn connect(socket: &Path) -> Result<Self, PatsmaError> {
        let stream = UnixStream::connect(socket)
            .map_err(|e| PatsmaError::io("connecting to daemon", socket, e))?;
        Ok(Self { stream })
    }

    /// Send one request, block for the response.
    pub fn request(&mut self, request: &Request) -> Result<Response, PatsmaError> {
        proto::write_frame(&mut self.stream, &request.to_wire())?;
        match proto::read_frame(&mut self.stream)? {
            Some(record) => Response::from_wire(&record),
            None => Err(PatsmaError::Protocol(
                "daemon closed the connection without answering".into(),
            )),
        }
    }

    /// Liveness probe: `(protocol version, sessions held, draining)`.
    pub fn ping(&mut self) -> Result<(u32, usize, bool), PatsmaError> {
        match self.request(&Request::Ping)? {
            Response::Pong {
                version,
                sessions,
                draining,
            } => Ok((version, sessions, draining)),
            Response::Draining => Err(PatsmaError::Draining),
            other => Err(unexpected("ping", &other)),
        }
    }

    /// Run (or fetch the converged result of) one session. Returns the
    /// report and whether it was answered from converged state.
    pub fn tune(
        &mut self,
        spec: SessionSpec,
        fresh: bool,
    ) -> Result<(SessionReport, bool), PatsmaError> {
        match self.request(&Request::Tune { spec, fresh })? {
            Response::Session { report, cached } => Ok((report, cached)),
            Response::Draining => Err(PatsmaError::Draining),
            Response::Error(reason) => Err(PatsmaError::Invalid(reason)),
            other => Err(unexpected("tune", &other)),
        }
    }

    /// The daemon's full registry.
    pub fn report(&mut self) -> Result<ServiceReport, PatsmaError> {
        match self.request(&Request::Report)? {
            Response::Report(report) => Ok(report),
            Response::Draining => Err(PatsmaError::Draining),
            Response::Error(reason) => Err(PatsmaError::Invalid(reason)),
            other => Err(unexpected("report", &other)),
        }
    }

    /// Re-tune drifted sessions at `budget` percent of their original
    /// iteration budget; returns `(drifted, fresh)` id lists.
    pub fn retune(
        &mut self,
        budget: u32,
        force: bool,
    ) -> Result<(Vec<String>, Vec<String>), PatsmaError> {
        match self.request(&Request::Retune { budget, force })? {
            Response::Retuned { drifted, fresh } => Ok((drifted, fresh)),
            Response::Draining => Err(PatsmaError::Draining),
            Response::Error(reason) => Err(PatsmaError::Invalid(reason)),
            other => Err(unexpected("retune", &other)),
        }
    }

    /// Look a context up in the daemon's tuned table. Returns the entry
    /// and whether it was an exact context hit (`false` = neighbouring
    /// size bucket — warm-start material, not a bypass). Lookups are
    /// reads: a draining daemon still answers them.
    pub fn lookup(&mut self, key: ContextKey) -> Result<Option<(TableEntry, bool)>, PatsmaError> {
        match self.request(&Request::Lookup { key })? {
            Response::Cell { entry, exact } => Ok(entry.map(|e| (e, exact))),
            Response::Draining => Err(PatsmaError::Draining),
            Response::Error(reason) => Err(PatsmaError::Invalid(reason)),
            other => Err(unexpected("lookup", &other)),
        }
    }

    /// Offer a converged cell to the daemon's tuned table; returns the
    /// stored confidence weight (the daemon may keep a higher-confidence
    /// cell it already holds).
    pub fn promote(&mut self, entry: TableEntry) -> Result<u32, PatsmaError> {
        match self.request(&Request::Promote { entry })? {
            Response::Promoted { weight } => Ok(weight),
            Response::Draining => Err(PatsmaError::Draining),
            Response::Error(reason) => Err(PatsmaError::Invalid(reason)),
            other => Err(unexpected("promote", &other)),
        }
    }

    /// Ask the daemon to drain and exit; the `draining` answer is the ack.
    pub fn shutdown(&mut self) -> Result<(), PatsmaError> {
        match self.request(&Request::Shutdown)? {
            Response::Draining => Ok(()),
            other => Err(unexpected("shutdown", &other)),
        }
    }
}

fn unexpected(what: &str, response: &Response) -> PatsmaError {
    PatsmaError::Protocol(format!("unexpected {what} response: {response:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Unique socket/registry paths per test — tests in one binary run
    /// concurrently and unix socket paths are global.
    fn scratch(tag: &str) -> (PathBuf, PathBuf, PathBuf) {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "patsma-daemon-unit-{tag}-{}-{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        (dir.join("daemon.sock"), dir.join("registry.txt"), dir)
    }

    #[test]
    fn daemon_serves_ping_tune_and_drains_cleanly() {
        let (socket, registry, dir) = scratch("basic");
        let config = DaemonConfig::new(&socket, &registry)
            .with_concurrency(2)
            .with_snapshot_interval(Duration::from_secs(3600));
        let handle = spawn(config).unwrap();

        let mut client = DaemonClient::connect(&socket).unwrap();
        let (version, sessions, draining) = client.ping().unwrap();
        assert_eq!(version, proto::PROTO_VERSION);
        assert_eq!(sessions, 0);
        assert!(!draining);

        let spec = SessionSpec::synthetic("unit", 48.0, 7).with_budget(4, 6);
        let (report, cached) = client.tune(spec.clone(), false).unwrap();
        assert_eq!(report.id, "unit");
        assert!(!cached);
        let (again, cached) = client.tune(spec, false).unwrap();
        assert!(cached, "second identical tune answers from state");
        assert_eq!(again, report);

        // A second daemon on a live socket is refused.
        let dup = DaemonConfig::new(&socket, &registry);
        assert!(matches!(spawn(dup), Err(PatsmaError::Invalid(_))));

        client.shutdown().unwrap();
        let summary = handle.wait().unwrap();
        assert!(summary.requests >= 4, "{summary:?}");
        assert_eq!(summary.sessions, 1);
        assert!(summary.snapshots >= 1, "final snapshot always written");
        assert!(!socket.exists(), "socket removed on drain");

        // The snapshot is a loadable registry holding the session.
        let persisted = ServiceReport::load(&registry).unwrap();
        assert_eq!(persisted.sessions.len(), 1);
        assert_eq!(persisted.sessions[0].id, "unit");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn spawn_replaces_a_stale_socket_file() {
        let (socket, registry, dir) = scratch("stale");
        // A dead daemon's leftover: a socket file nobody answers on.
        let stale = UnixListener::bind(&socket).unwrap();
        drop(stale);
        assert!(socket.exists());

        let handle = spawn(
            DaemonConfig::new(&socket, &registry)
                .with_concurrency(1)
                .with_snapshot_interval(Duration::from_secs(3600)),
        )
        .unwrap();
        let mut client = DaemonClient::connect(&socket).unwrap();
        client.ping().unwrap();
        client.shutdown().unwrap();
        handle.wait().unwrap();
        let _ = std::fs::remove_dir_all(dir);
    }
}
