//! Shared point-evaluation cache.
//!
//! Tuning sessions over the same workload keep re-proposing the same
//! quantised points: CSA's centre probe, integer domains collapsing many
//! internal candidates onto one lattice value, and independent sessions
//! exploring overlapping regions. The cache memoises `cost` by
//! **(workload fingerprint, quantised user-domain point)** so a repeated
//! candidate — within one session or across concurrent sessions — is free.
//!
//! Keys are the *exact* user-domain values the application is handed, one
//! `f64` per dimension, compared **bit for bit** (after normalising `-0.0`
//! to `0.0`). For integer domains those values come out of
//! [`crate::tuner::quantize_integer`], so two internal candidates that
//! round to the same lattice point intentionally collide (that is the hit);
//! for float domains every distinct value is a distinct key — quantising
//! floats onto an integer lattice here would merge genuinely different
//! candidates into one entry and hand the optimizer a stale cost.
//!
//! Sharded `Mutex<HashMap>` keeps contention off the hot path without any
//! external crate. Two threads that miss on the same key concurrently may
//! both evaluate; the second insert overwrites with an identical value for
//! deterministic targets, so only effort (never correctness) is lost.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of independent shards (power of two; fixed — the cache is small
/// and the point is lock splitting, not capacity tuning).
const SHARDS: usize = 16;

/// FNV-1a over a byte stream — a stable, dependency-free hash for
/// fingerprints and shard selection (`DefaultHasher` is not guaranteed
/// stable across releases, and registry files outlive processes).
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable fingerprint of a workload descriptor string.
pub fn fingerprint_str(s: &str) -> u64 {
    fnv1a(s.bytes())
}

/// Bit pattern of one key coordinate. `-0.0` is folded into `0.0` so the
/// two representations of zero share an entry; NaNs are rejected upstream
/// (a NaN candidate never reaches the cache).
#[inline]
fn coord_bits(v: f64) -> u64 {
    if v == 0.0 {
        0.0f64.to_bits()
    } else {
        v.to_bits()
    }
}

fn point_bits(point: &[f64]) -> Vec<u64> {
    point.iter().map(|&v| coord_bits(v)).collect()
}

fn key_hash(fingerprint: u64, point: &[f64]) -> u64 {
    let mut h = fnv1a(fingerprint.to_le_bytes());
    for &v in point {
        for b in coord_bits(v).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Aggregate cache counters (monotonic over the cache's lifetime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to evaluate.
    pub misses: u64,
    /// Distinct (fingerprint, point) entries resident.
    pub entries: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 for an unused cache).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Concurrent point-evaluation cache (see module docs).
pub struct PointCache {
    shards: Vec<Mutex<HashMap<(u64, Vec<u64>), f64>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for PointCache {
    fn default() -> Self {
        Self::new()
    }
}

impl PointCache {
    /// An empty cache.
    pub fn new() -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    fn shard(&self, fingerprint: u64, point: &[f64]) -> &Mutex<HashMap<(u64, Vec<u64>), f64>> {
        &self.shards[(key_hash(fingerprint, point) as usize) % SHARDS]
    }

    /// Cached cost for the point, if any. Does **not** touch the hit/miss
    /// counters (use [`get_or_compute`](Self::get_or_compute) for counted
    /// access).
    pub fn peek(&self, fingerprint: u64, point: &[f64]) -> Option<f64> {
        let shard = self.shard(fingerprint, point).lock().unwrap();
        shard.get(&(fingerprint, point_bits(point))).copied()
    }

    /// Insert (or overwrite) a point's cost.
    pub fn insert(&self, fingerprint: u64, point: &[f64], cost: f64) {
        let mut shard = self.shard(fingerprint, point).lock().unwrap();
        shard.insert((fingerprint, point_bits(point)), cost);
    }

    /// Counted lookup: returns `(cost, was_hit)`, evaluating and inserting
    /// on a miss. The shard lock is **not** held during `eval` (evaluations
    /// are wall-clock measurements or real kernel runs), so concurrent
    /// misses on one key may evaluate redundantly — see module docs.
    pub fn get_or_compute(
        &self,
        fingerprint: u64,
        point: &[f64],
        eval: impl FnOnce() -> f64,
    ) -> (f64, bool) {
        if let Some(cost) = self.peek(fingerprint, point) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (cost, true);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let cost = eval();
        self.insert(fingerprint, point, cost);
        (cost, false)
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when no entry is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_semantics() {
        let cache = PointCache::new();
        let fp = fingerprint_str("synthetic/best=48/dim=1");
        let mut evals = 0;
        let (c1, hit1) = cache.get_or_compute(fp, &[32.0], || {
            evals += 1;
            1.25
        });
        assert!(!hit1);
        assert_eq!(c1, 1.25);
        let (c2, hit2) = cache.get_or_compute(fp, &[32.0], || {
            evals += 1;
            f64::NAN // must never be called
        });
        assert!(hit2);
        assert_eq!(c2, 1.25);
        assert_eq!(evals, 1, "hit must not re-evaluate");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn integer_rounding_collisions_are_hits() {
        // Two internal candidates that quantise onto the same lattice value
        // share one key — by design, not by accident.
        use crate::tuner::{quantize_integer, rescale_internal};
        let cache = PointCache::new();
        let fp = fingerprint_str("synthetic/best=24/dim=1");
        let (lo, hi) = (1.0, 64.0);
        // Both internal points land on user value 33 after rounding.
        let a = quantize_integer(rescale_internal(0.004, lo, hi), lo, hi);
        let b = quantize_integer(rescale_internal(-0.004, lo, hi), lo, hi);
        assert_eq!(a, b, "test premise: both candidates round to one point");
        let (_, h1) = cache.get_or_compute(fp, &[a], || 2.0);
        let (c, h2) = cache.get_or_compute(fp, &[b], || 99.0);
        assert!(!h1);
        assert!(h2, "rounded collision must be a cache hit");
        assert_eq!(c, 2.0);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn float_candidates_do_not_collapse() {
        // The float-domain fix: sub-integer differences are distinct keys.
        // Quantising these to an integer lattice would merge them and hand
        // the second candidate the first one's cost.
        let cache = PointCache::new();
        let fp = fingerprint_str("synthetic-float");
        let (_, h1) = cache.get_or_compute(fp, &[32.25], || 1.0);
        let (c2, h2) = cache.get_or_compute(fp, &[32.75], || 2.0);
        assert!(!h1);
        assert!(!h2, "distinct float candidates must be distinct entries");
        assert_eq!(c2, 2.0);
        assert_eq!(cache.len(), 2);
        // Bit-exact repeat is still a hit.
        let (c3, h3) = cache.get_or_compute(fp, &[32.25], || 99.0);
        assert!(h3);
        assert_eq!(c3, 1.0);
    }

    #[test]
    fn negative_zero_shares_the_zero_entry() {
        let cache = PointCache::new();
        let fp = fingerprint_str("zeros");
        cache.insert(fp, &[0.0], 7.0);
        assert_eq!(cache.peek(fp, &[-0.0]), Some(7.0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_fingerprints_do_not_collide() {
        let cache = PointCache::new();
        let fa = fingerprint_str("workload-a");
        let fb = fingerprint_str("workload-b");
        assert_ne!(fa, fb);
        cache.insert(fa, &[5.0], 1.0);
        cache.insert(fb, &[5.0], 2.0);
        assert_eq!(cache.peek(fa, &[5.0]), Some(1.0));
        assert_eq!(cache.peek(fb, &[5.0]), Some(2.0));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn distinct_points_and_dims_do_not_collide() {
        let cache = PointCache::new();
        let fp = fingerprint_str("w");
        cache.insert(fp, &[1.0, 2.0], 1.0);
        cache.insert(fp, &[2.0, 1.0], 2.0);
        cache.insert(fp, &[1.0], 3.0);
        assert_eq!(cache.peek(fp, &[1.0, 2.0]), Some(1.0));
        assert_eq!(cache.peek(fp, &[2.0, 1.0]), Some(2.0));
        assert_eq!(cache.peek(fp, &[1.0]), Some(3.0));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let cache = PointCache::new();
        let fp = fingerprint_str("shared");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cache = &cache;
                s.spawn(move || {
                    for p in 0..64 {
                        let point = [p as f64];
                        let (c, _) = cache.get_or_compute(fp, &point, || p as f64 * 2.0);
                        assert_eq!(c, p as f64 * 2.0);
                    }
                });
            }
        });
        assert_eq!(cache.len(), 64);
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 4 * 64);
        assert!(s.misses >= 64);
    }

    #[test]
    fn fnv_is_stable() {
        // Pinned digest: registry fingerprints must not drift between runs
        // or releases.
        assert_eq!(fingerprint_str(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fingerprint_str("a"), 0xaf63_dc4c_8601_ec8c);
    }
}
