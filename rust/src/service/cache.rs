//! Shared point-evaluation cache.
//!
//! Tuning sessions over the same workload keep re-proposing the same
//! quantised points: CSA's centre probe, integer domains collapsing many
//! internal candidates onto one lattice value, and independent sessions
//! exploring overlapping regions. The cache memoises `cost` by
//! **(workload fingerprint, quantised user-domain point)** so a repeated
//! candidate — within one session or across concurrent sessions — is free.
//!
//! Keys are the *exact* user-domain values the application is handed, one
//! `f64` per dimension, compared **bit for bit** (after normalising `-0.0`
//! to `0.0`). For integer domains those values come out of
//! [`crate::tuner::quantize_integer`], so two internal candidates that
//! round to the same lattice point intentionally collide (that is the hit);
//! for float domains every distinct value is a distinct key — quantising
//! floats onto an integer lattice here would merge genuinely different
//! candidates into one entry and hand the optimizer a stale cost.
//!
//! Sharded `Mutex<HashMap>` keeps contention off the hot path without any
//! external crate. Two threads that miss on the same key concurrently may
//! both evaluate; the second insert overwrites with an identical value for
//! deterministic targets, so only effort (never correctness) is lost.
//!
//! **Residency is bounded.** A batch-mode service dies with the process, but
//! the daemon never exits — an unbounded memo table is a slow OOM. Every
//! entry carries a last-touch stamp from a global monotonic clock; when a
//! shard is at capacity an insert evicts that shard's least-recently-used
//! entry first. The cap divides evenly across shards (so eviction needs no
//! cross-shard coordination) and evictions are counted in [`CacheStats`],
//! surfaced by `patsma service report`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Number of independent shards (power of two; fixed — the cache is small
/// and the point is lock splitting, not capacity tuning).
const SHARDS: usize = 16;

/// Default residency bound (entries). One entry is a key of ~`8 + 8·dim`
/// bytes plus an `f64` cost, so the default caps the cache in the
/// few-megabytes range while staying far above any single batch's working
/// set.
pub const DEFAULT_CACHE_CAP: usize = 65_536;

/// FNV-1a over a byte stream — a stable, dependency-free hash for
/// fingerprints and shard selection (`DefaultHasher` is not guaranteed
/// stable across releases, and registry files outlive processes).
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Stable fingerprint of a workload descriptor string.
pub fn fingerprint_str(s: &str) -> u64 {
    fnv1a(s.bytes())
}

/// Bit pattern of one key coordinate. `-0.0` is folded into `0.0` so the
/// two representations of zero share an entry; NaNs are rejected upstream
/// (a NaN candidate never reaches the cache).
#[inline]
fn coord_bits(v: f64) -> u64 {
    if v == 0.0 {
        0.0f64.to_bits()
    } else {
        v.to_bits()
    }
}

fn point_bits(point: &[f64]) -> Vec<u64> {
    point.iter().map(|&v| coord_bits(v)).collect()
}

fn key_hash(fingerprint: u64, point: &[f64]) -> u64 {
    let mut h = fnv1a(fingerprint.to_le_bytes());
    for &v in point {
        for b in coord_bits(v).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Aggregate cache counters (monotonic over the cache's lifetime, except
/// `entries`/`cap` which describe the current residency).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that had to evaluate.
    pub misses: u64,
    /// Distinct (fingerprint, point) entries resident.
    pub entries: usize,
    /// Entries displaced by the LRU bound.
    pub evictions: u64,
    /// Residency bound the cache enforces (total across shards).
    pub cap: usize,
}

impl CacheStats {
    /// Hit fraction in `[0, 1]` (0 for an unused cache).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One resident cost plus its last-touch stamp (for LRU eviction).
struct Entry {
    cost: f64,
    stamp: u64,
}

/// Concurrent point-evaluation cache (see module docs).
pub struct PointCache {
    shards: Vec<Mutex<HashMap<(u64, Vec<u64>), Entry>>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    /// Global recency clock; every touch stamps the entry with the next
    /// tick. Relaxed is fine: LRU is a heuristic, not a happens-before edge.
    clock: AtomicU64,
    per_shard_cap: usize,
}

impl Default for PointCache {
    fn default() -> Self {
        Self::new()
    }
}

impl PointCache {
    /// An empty cache with the default residency bound
    /// ([`DEFAULT_CACHE_CAP`]).
    pub fn new() -> Self {
        Self::with_cap(DEFAULT_CACHE_CAP)
    }

    /// An empty cache bounded to roughly `cap` entries. The bound divides
    /// evenly across shards (rounding up to at least one entry per shard),
    /// so the enforced total is `cap` rounded to a shard multiple — read it
    /// back via [`cap`](Self::cap).
    pub fn with_cap(cap: usize) -> Self {
        Self {
            shards: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            clock: AtomicU64::new(0),
            per_shard_cap: (cap / SHARDS).max(1),
        }
    }

    /// The residency bound actually enforced (total entries across shards).
    pub fn cap(&self) -> usize {
        self.per_shard_cap * SHARDS
    }

    fn shard(&self, fingerprint: u64, point: &[f64]) -> &Mutex<HashMap<(u64, Vec<u64>), Entry>> {
        &self.shards[(key_hash(fingerprint, point) as usize) % SHARDS]
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed)
    }

    /// Cached cost for the point, if any; refreshes the entry's recency.
    /// Does **not** touch the hit/miss counters (use
    /// [`get_or_compute`](Self::get_or_compute) for counted access).
    pub fn peek(&self, fingerprint: u64, point: &[f64]) -> Option<f64> {
        let stamp = self.tick();
        let mut shard = self.shard(fingerprint, point).lock().unwrap();
        shard.get_mut(&(fingerprint, point_bits(point))).map(|e| {
            e.stamp = stamp;
            e.cost
        })
    }

    /// Insert (or overwrite) a point's cost, evicting the shard's
    /// least-recently-used entry first when the shard is at capacity.
    pub fn insert(&self, fingerprint: u64, point: &[f64], cost: f64) {
        let stamp = self.tick();
        let mut shard = self.shard(fingerprint, point).lock().unwrap();
        let key = (fingerprint, point_bits(point));
        if let Some(e) = shard.get_mut(&key) {
            e.cost = cost;
            e.stamp = stamp;
            return;
        }
        if shard.len() >= self.per_shard_cap {
            if let Some(victim) = shard
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
            {
                shard.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.insert(key, Entry { cost, stamp });
    }

    /// Counted lookup: returns `(cost, was_hit)`, evaluating and inserting
    /// on a miss. The shard lock is **not** held during `eval` (evaluations
    /// are wall-clock measurements or real kernel runs), so concurrent
    /// misses on one key may evaluate redundantly — see module docs.
    pub fn get_or_compute(
        &self,
        fingerprint: u64,
        point: &[f64],
        eval: impl FnOnce() -> f64,
    ) -> (f64, bool) {
        if let Some(cost) = self.peek(fingerprint, point) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (cost, true);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let cost = eval();
        self.insert(fingerprint, point, cost);
        (cost, false)
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when no entry is resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Counter snapshot.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
            evictions: self.evictions.load(Ordering::Relaxed),
            cap: self.cap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_hit_semantics() {
        let cache = PointCache::new();
        let fp = fingerprint_str("synthetic/best=48/dim=1");
        let mut evals = 0;
        let (c1, hit1) = cache.get_or_compute(fp, &[32.0], || {
            evals += 1;
            1.25
        });
        assert!(!hit1);
        assert_eq!(c1, 1.25);
        let (c2, hit2) = cache.get_or_compute(fp, &[32.0], || {
            evals += 1;
            f64::NAN // must never be called
        });
        assert!(hit2);
        assert_eq!(c2, 1.25);
        assert_eq!(evals, 1, "hit must not re-evaluate");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.evictions, 0);
        assert_eq!(s.cap, DEFAULT_CACHE_CAP);
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn integer_rounding_collisions_are_hits() {
        // Two internal candidates that quantise onto the same lattice value
        // share one key — by design, not by accident.
        use crate::tuner::{quantize_integer, rescale_internal};
        let cache = PointCache::new();
        let fp = fingerprint_str("synthetic/best=24/dim=1");
        let (lo, hi) = (1.0, 64.0);
        // Both internal points land on user value 33 after rounding.
        let a = quantize_integer(rescale_internal(0.004, lo, hi), lo, hi);
        let b = quantize_integer(rescale_internal(-0.004, lo, hi), lo, hi);
        assert_eq!(a, b, "test premise: both candidates round to one point");
        let (_, h1) = cache.get_or_compute(fp, &[a], || 2.0);
        let (c, h2) = cache.get_or_compute(fp, &[b], || 99.0);
        assert!(!h1);
        assert!(h2, "rounded collision must be a cache hit");
        assert_eq!(c, 2.0);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn float_candidates_do_not_collapse() {
        // The float-domain fix: sub-integer differences are distinct keys.
        // Quantising these to an integer lattice would merge them and hand
        // the second candidate the first one's cost.
        let cache = PointCache::new();
        let fp = fingerprint_str("synthetic-float");
        let (_, h1) = cache.get_or_compute(fp, &[32.25], || 1.0);
        let (c2, h2) = cache.get_or_compute(fp, &[32.75], || 2.0);
        assert!(!h1);
        assert!(!h2, "distinct float candidates must be distinct entries");
        assert_eq!(c2, 2.0);
        assert_eq!(cache.len(), 2);
        // Bit-exact repeat is still a hit.
        let (c3, h3) = cache.get_or_compute(fp, &[32.25], || 99.0);
        assert!(h3);
        assert_eq!(c3, 1.0);
    }

    #[test]
    fn negative_zero_shares_the_zero_entry() {
        let cache = PointCache::new();
        let fp = fingerprint_str("zeros");
        cache.insert(fp, &[0.0], 7.0);
        assert_eq!(cache.peek(fp, &[-0.0]), Some(7.0));
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_fingerprints_do_not_collide() {
        let cache = PointCache::new();
        let fa = fingerprint_str("workload-a");
        let fb = fingerprint_str("workload-b");
        assert_ne!(fa, fb);
        cache.insert(fa, &[5.0], 1.0);
        cache.insert(fb, &[5.0], 2.0);
        assert_eq!(cache.peek(fa, &[5.0]), Some(1.0));
        assert_eq!(cache.peek(fb, &[5.0]), Some(2.0));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn distinct_points_and_dims_do_not_collide() {
        let cache = PointCache::new();
        let fp = fingerprint_str("w");
        cache.insert(fp, &[1.0, 2.0], 1.0);
        cache.insert(fp, &[2.0, 1.0], 2.0);
        cache.insert(fp, &[1.0], 3.0);
        assert_eq!(cache.peek(fp, &[1.0, 2.0]), Some(1.0));
        assert_eq!(cache.peek(fp, &[2.0, 1.0]), Some(2.0));
        assert_eq!(cache.peek(fp, &[1.0]), Some(3.0));
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let cache = PointCache::new();
        let fp = fingerprint_str("shared");
        std::thread::scope(|s| {
            for _ in 0..4 {
                let cache = &cache;
                s.spawn(move || {
                    for p in 0..64 {
                        let point = [p as f64];
                        let (c, _) = cache.get_or_compute(fp, &point, || p as f64 * 2.0);
                        assert_eq!(c, p as f64 * 2.0);
                    }
                });
            }
        });
        assert_eq!(cache.len(), 64);
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 4 * 64);
        assert!(s.misses >= 64);
    }

    #[test]
    fn lru_bound_caps_residency_and_counts_evictions() {
        // 16 shards × 1 entry each: insert far more than the cap and the
        // table must stay bounded, with the displaced entries counted.
        let cache = PointCache::with_cap(16);
        assert_eq!(cache.cap(), 16);
        let fp = fingerprint_str("daemon-lifetime");
        let inserted = 200u64;
        for p in 0..inserted {
            cache.insert(fp, &[p as f64], p as f64);
        }
        let s = cache.stats();
        assert!(
            s.entries <= s.cap,
            "residency {} must respect cap {}",
            s.entries,
            s.cap
        );
        assert_eq!(
            s.evictions,
            inserted - s.entries as u64,
            "every displaced entry is counted"
        );
    }

    #[test]
    fn lru_evicts_the_cold_entry_not_the_hot_one() {
        // One shard (cap 16 / 16 shards = 1 per shard would interleave with
        // hashing; instead drive a single shard by reusing one key's shard):
        // keep touching `hot`; inserts of colder keys in the same shard must
        // displace each other, never the hot entry... shard placement is
        // hash-driven, so assert the observable contract instead: a key
        // touched immediately before an insert burst survives longer than
        // untouched keys on average. Deterministically: with per-shard cap 1,
        // after touching `hot` and inserting a colder key into a *different*
        // shard, `hot` is still resident.
        let cache = PointCache::with_cap(16); // per-shard cap 1
        let fp = fingerprint_str("hot-cold");
        // Find two points in distinct shards.
        let hot = [1.0];
        let mut other = None;
        for p in 2..64 {
            let cand = [p as f64];
            let a = (key_hash(fp, &hot) as usize) % SHARDS;
            let b = (key_hash(fp, &cand) as usize) % SHARDS;
            if a != b {
                other = Some(cand);
                break;
            }
        }
        let other = other.expect("some point hashes to another shard");
        cache.insert(fp, &hot, 10.0);
        cache.insert(fp, &other, 20.0);
        assert_eq!(cache.peek(fp, &hot), Some(10.0));
        assert_eq!(cache.peek(fp, &other), Some(20.0));
        // Same-shard displacement: re-inserting a *new* key into the hot
        // entry's shard evicts the LRU occupant of that shard only.
        let mut same = None;
        for p in 64..256 {
            let cand = [p as f64];
            if (key_hash(fp, &cand) as usize) % SHARDS == (key_hash(fp, &hot) as usize) % SHARDS {
                same = Some(cand);
                break;
            }
        }
        let same = same.expect("some point shares the hot shard");
        cache.insert(fp, &same, 30.0);
        assert_eq!(cache.peek(fp, &hot), None, "LRU occupant displaced");
        assert_eq!(cache.peek(fp, &same), Some(30.0));
        assert_eq!(cache.peek(fp, &other), Some(20.0), "other shard untouched");
        assert!(cache.stats().evictions >= 1);
    }
}
