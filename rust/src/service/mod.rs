//! The concurrent multi-session tuning service.
//!
//! The paper tunes one parameter set for one application at a time. A
//! production deployment faces *many* tuning scenarios at once — several
//! workloads × optimizers × domains, re-tuned as conditions change (cf. HPX
//! Smart Executors and Karcher & Pankratius's concurrent-autotuning work).
//! This module is the scaling substrate for that: it runs a batch of
//! [`SessionSpec`]s concurrently and stacks three multipliers on top of the
//! staged optimizer core:
//!
//! 1. **Inter-session concurrency** — sessions execute on a persistent
//!    [`crate::sched::ThreadPool`] with bounded parallelism (the service's
//!    `concurrency`), claimed FCFS via `Schedule::Dynamic(1)`.
//! 2. **Intra-session batching** — each optimizer iteration's candidate
//!    population is pulled with [`NumericalOptimizer::run_batch`] and
//!    evaluated as a batch instead of the staged one-at-a-time loop (CSA
//!    overrides the hook to expose whole populations; every other optimizer
//!    degrades to batches of one). Pure targets evaluate their batch in
//!    parallel when the session is not itself inside a pool region.
//! 3. **Cross-session caching** — evaluations are memoised in a shared
//!    [`PointCache`] keyed by (workload fingerprint, exact user-domain
//!    point), so a candidate repeated anywhere — within a session or across
//!    sessions — is free.
//!
//! ## Warm-started re-tuning
//!
//! Sessions no longer have to cold-start. A finished session exports its
//! optimizer snapshot ([`crate::optimizer::OptimizerState`]) into a
//! [`SessionState`] that the registry persists alongside the results, keyed
//! by workload fingerprint and [`EnvFingerprint`]. A later run can seed a
//! session from that state with [`SessionSpec::warm_start`]: the optimizer
//! restarts with `ResetLevel::Soft` semantics from the persisted solutions
//! and (for CSA) the persisted annealing temperature, re-measures the old
//! best point first and refines from there — reaching the optimum region
//! with strictly fewer evaluations than a cold start (pinned by
//! `tests/service.rs`). `patsma service retune` automates the loop: load
//! the registry, compare each state's environment fingerprint with the
//! current one, and re-tune drifted sessions at a reduced budget.
//!
//! Determinism: a session's optimizer trajectory depends only on its seed,
//! its warm-start state and the evaluated costs. For deterministic targets
//! (the `synthetic` landscape) cached costs equal fresh ones exactly, so a
//! session's result is bit-identical whether it runs alone, serially, or
//! among concurrent sessions — `tests/service.rs` pins this.
//!
//! Real workloads go through the same surface: `WorkloadSpec::Named`
//! sessions tune any [`workloads::NAMES`] entry over its typed
//! [`Workload::space`], and `WorkloadSpec::NamedJoint` sessions tune it
//! **jointly** over [`Workload::joint_space`] — cache keys are the decoded
//! typed cell and the best cell's label is persisted into the registry
//! (`patsma service run --workload spmv --joint`).
//!
//! Results land in a [`registry`] the CLI (`patsma service
//! run|report|retune`) and the coordinator (experiment E12) consume.
//!
//! # Examples
//!
//! Run a batch of synthetic sessions and inspect the report (concurrency 1
//! keeps the cache counters deterministic; higher values overlap sessions):
//!
//! ```
//! use patsma::service::{SessionSpec, TuningService};
//!
//! let service = TuningService::new(1);
//! let specs = vec![
//!     SessionSpec::synthetic("a", 48.0, 1),
//!     SessionSpec::synthetic("b", 48.0, 1),
//! ];
//! let report = service.run(&specs).unwrap();
//! assert_eq!(report.sessions.len(), 2);
//! // Identical sessions repeat candidates, so the shared cache sees hits.
//! assert!(report.cache.hits > 0);
//! ```

pub mod cache;
pub mod daemon;
pub mod proto;
pub mod registry;
pub mod shard;
pub mod state;

pub use cache::{fingerprint_str, CacheStats, DEFAULT_CACHE_CAP, PointCache};
pub use daemon::{DaemonClient, DaemonConfig, DaemonHandle, DrainSummary};
pub use proto::{Request, Response};
pub use registry::{ParetoRecord, ServiceReport, SessionReport};
pub use shard::{DEFAULT_SHARDS, SessionEntry, ShardedSessions};
pub use state::{EnvFingerprint, SessionState};

use crate::adaptive::table::{SharedTunedTable, TableEntry, TableHit};
use crate::optimizer::{
    Csa, CsaConfig, GridSearch, NelderMead, NelderMeadConfig, NumericalOptimizer, ParticleSwarm,
    PsoConfig, RandomSearch, SaConfig, SimulatedAnnealing,
};
use crate::sched::{Schedule, ThreadPool};
use crate::space::{CostVector, Dim, MultiObjective, ObjectiveSpec, ParetoFront, SearchSpace};
use crate::tuner::{quantize_integer, rescale_internal};
use crate::workloads::{self, synthetic, Workload};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Which optimizer a session drives (the string forms match the CLI).
///
/// # Examples
///
/// ```
/// use patsma::service::OptimizerSpec;
///
/// assert_eq!(OptimizerSpec::parse("csa").unwrap(), OptimizerSpec::Csa);
/// assert_eq!(OptimizerSpec::Csa.name(), "csa");
/// assert!(OptimizerSpec::parse("bogus").is_err());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerSpec {
    /// Coupled Simulated Annealing (the paper's primary method).
    Csa,
    /// Nelder–Mead simplex.
    NelderMead,
    /// Single uncoupled SA chain.
    Sa,
    /// Uniform random search.
    Random,
    /// Particle swarm.
    Pso,
    /// Exhaustive lattice.
    Grid,
}

impl OptimizerSpec {
    /// Parse the CLI form (`csa|nm|sa|random|pso|grid`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "csa" => Self::Csa,
            "nm" => Self::NelderMead,
            "sa" => Self::Sa,
            "random" => Self::Random,
            "pso" => Self::Pso,
            "grid" => Self::Grid,
            other => bail!("unknown optimizer {other:?} (csa|nm|sa|random|pso|grid)"),
        })
    }

    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Csa => "csa",
            Self::NelderMead => "nm",
            Self::Sa => "sa",
            Self::Random => "random",
            Self::Pso => "pso",
            Self::Grid => "grid",
        }
    }

    /// Instantiate with the session's budget, mirroring the CLI's optimizer
    /// factory: population methods read (`num_opt`, `max_iter`) directly,
    /// sequential methods get the equalised `num_opt * max_iter` evaluation
    /// budget.
    pub fn build(
        &self,
        dim: usize,
        num_opt: usize,
        max_iter: usize,
        seed: u64,
    ) -> Box<dyn NumericalOptimizer> {
        match self {
            Self::Csa => Box::new(Csa::new(
                CsaConfig::new(dim, num_opt, max_iter).with_seed(seed),
            )),
            Self::NelderMead => Box::new(NelderMead::new(
                NelderMeadConfig::new(dim, 1e-9, num_opt * max_iter).with_seed(seed),
            )),
            Self::Sa => Box::new(SimulatedAnnealing::new(
                SaConfig::new(dim, num_opt * max_iter).with_seed(seed),
            )),
            Self::Random => Box::new(RandomSearch::new(dim, num_opt * max_iter, seed)),
            Self::Pso => Box::new(ParticleSwarm::new(
                PsoConfig::new(dim, num_opt, max_iter).with_seed(seed),
            )),
            Self::Grid => Box::new(GridSearch::new(dim, (num_opt * max_iter).max(2))),
        }
    }
}

/// Whether a domain's points live on the integer lattice or are handed to
/// the application as exact floating-point values. This is part of the cost
/// landscape's identity: it decides both what the application receives and
/// what the evaluation-cache key is.
///
/// # Examples
///
/// ```
/// use patsma::service::PointKind;
///
/// assert_eq!(PointKind::parse("int").unwrap(), PointKind::Integer);
/// assert_eq!(PointKind::Float.name(), "float");
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PointKind {
    /// Candidates are rounded onto the integer lattice
    /// ([`quantize_integer`]) — chunk sizes, block sizes, thread counts.
    Integer,
    /// Candidates keep their exact (clamped) floating-point value —
    /// relaxation factors, thresholds. Distinct float candidates are
    /// distinct cache keys; quantising them would merge genuinely different
    /// configurations into one entry.
    Float,
}

impl PointKind {
    /// Descriptor token (`int` / `float`).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Integer => "int",
            Self::Float => "float",
        }
    }

    /// Parse a descriptor token.
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "int" => Self::Integer,
            "float" => Self::Float,
            other => bail!("unknown point kind {other:?} (int|float)"),
        })
    }
}

/// What a session evaluates.
///
/// # Examples
///
/// The descriptor round-trip `retune` relies on:
///
/// ```
/// use patsma::service::WorkloadSpec;
///
/// let spec = WorkloadSpec::Named("spmv".into());
/// assert_eq!(spec.descriptor(), "named/spmv");
/// assert_eq!(WorkloadSpec::parse_descriptor("named/spmv").unwrap(), spec);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// The deterministic closed-form chunk-cost landscape
    /// ([`synthetic::chunk_cost_model`], summed over dimensions, minimum at
    /// `optimum` per coordinate). Pure: batch members evaluate in parallel
    /// and cached costs are exact.
    Synthetic {
        /// Per-coordinate location of the cost minimum (user domain).
        optimum: f64,
        /// Number of tuned parameters.
        dim: usize,
        /// Scalar lower bound, broadcast to all dimensions.
        lo: f64,
        /// Scalar upper bound, broadcast to all dimensions.
        hi: f64,
        /// Integer-lattice or exact-float candidates.
        kind: PointKind,
    },
    /// The deterministic **joint** `(schedule kind, chunk)` landscape
    /// ([`synthetic::joint_cost_model`]) over the typed space
    /// [`Schedule::joint_space`]: a categorical kind dimension and an
    /// integer chunk in `[lo, hi]`. Pure, like `Synthetic` — and typed, so
    /// the evaluation-cache key is the decoded cell: `dynamic,chunk=32`
    /// and `guided,chunk=32` never collide.
    SyntheticJoint {
        /// Chunk location of the dynamic-kind cost minimum (user domain).
        optimum: f64,
        /// Inclusive chunk lower bound (≥ 1).
        lo: i64,
        /// Inclusive chunk upper bound.
        hi: i64,
    },
    /// A real shared-memory workload from [`workloads::by_name`], tuned
    /// over its typed [`Workload::space`]; the cost is the measured
    /// wall-clock of one target iteration (after `ignore` stabilisation
    /// iterations), so cached costs are the *measured* value of the point's
    /// first run. Cache keys are the decoded typed cell.
    Named(String),
    /// A registry workload tuned **jointly** over its
    /// [`Workload::joint_space`] — the `(schedule kind, chunk, …)` typed
    /// surface. Cache keys are the decoded cell, so `dynamic,32` and
    /// `guided,32` never collide, and the best cell is persisted as the
    /// registry-v2 `label=` key.
    NamedJoint(String),
}

impl WorkloadSpec {
    /// Whitespace-free descriptor — the registry label and the cache
    /// fingerprint input. Everything that changes the cost landscape must
    /// appear here, or distinct landscapes would share cache entries.
    pub fn descriptor(&self) -> String {
        match self {
            Self::Synthetic {
                optimum,
                dim,
                lo,
                hi,
                kind,
            } => format!(
                "synthetic/opt={optimum}/dim={dim}/lo={lo}/hi={hi}/kind={}",
                kind.name()
            ),
            Self::SyntheticJoint { optimum, lo, hi } => {
                format!("synthetic-joint/opt={optimum}/lo={lo}/hi={hi}")
            }
            Self::Named(name) => format!("named/{name}"),
            Self::NamedJoint(name) => format!("named-joint/{name}"),
        }
    }

    /// The typed search space of a *synthetic* joint workload; `None` for
    /// plain boxes and for named workloads (their spaces come from the
    /// constructed [`Workload`] instance, which depends on the size).
    pub fn space(&self) -> Option<SearchSpace> {
        match self {
            Self::SyntheticJoint { lo, hi, .. } => Some(SearchSpace::new(vec![
                Dim::categorical(&Schedule::KINDS),
                Dim::Int { lo: *lo, hi: *hi },
            ])),
            _ => None,
        }
    }

    /// Parse a [`descriptor`](Self::descriptor) back into a spec — how
    /// `patsma service retune` rebuilds sessions from persisted state.
    /// Unknown descriptor segments are ignored (forward compatibility);
    /// the round trip `parse_descriptor(d).descriptor() == d` holds for
    /// every descriptor this version emits.
    pub fn parse_descriptor(text: &str) -> Result<Self> {
        if let Some(name) = text.strip_prefix("named-joint/") {
            if name.is_empty() {
                bail!("empty workload name in descriptor {text:?}");
            }
            return Ok(Self::NamedJoint(name.to_string()));
        }
        if let Some(name) = text.strip_prefix("named/") {
            if name.is_empty() {
                bail!("empty workload name in descriptor {text:?}");
            }
            return Ok(Self::Named(name.to_string()));
        }
        if let Some(rest) = text.strip_prefix("synthetic-joint/") {
            let (mut optimum, mut lo, mut hi) = (None, None, None);
            for seg in rest.split('/') {
                let (k, v) = seg
                    .split_once('=')
                    .with_context(|| format!("bad descriptor segment {seg:?}"))?;
                match k {
                    "opt" => optimum = Some(v.parse::<f64>().context("bad opt")?),
                    "lo" => lo = Some(v.parse::<i64>().context("bad lo")?),
                    "hi" => hi = Some(v.parse::<i64>().context("bad hi")?),
                    _ => {} // forward compatibility
                }
            }
            return Ok(Self::SyntheticJoint {
                optimum: optimum.context("descriptor missing opt")?,
                lo: lo.context("descriptor missing lo")?,
                hi: hi.context("descriptor missing hi")?,
            });
        }
        let rest = text
            .strip_prefix("synthetic/")
            .with_context(|| format!("unrecognised workload descriptor {text:?}"))?;
        let (mut optimum, mut dim, mut lo, mut hi, mut kind) = (None, None, None, None, None);
        for seg in rest.split('/') {
            let (k, v) = seg
                .split_once('=')
                .with_context(|| format!("bad descriptor segment {seg:?}"))?;
            match k {
                "opt" => optimum = Some(v.parse::<f64>().context("bad opt")?),
                "dim" => dim = Some(v.parse::<usize>().context("bad dim")?),
                "lo" => lo = Some(v.parse::<f64>().context("bad lo")?),
                "hi" => hi = Some(v.parse::<f64>().context("bad hi")?),
                "kind" => kind = Some(PointKind::parse(v)?),
                _ => {} // forward compatibility
            }
        }
        Ok(Self::Synthetic {
            optimum: optimum.context("descriptor missing opt")?,
            dim: dim.context("descriptor missing dim")?,
            lo: lo.context("descriptor missing lo")?,
            hi: hi.context("descriptor missing hi")?,
            kind: kind.context("descriptor missing kind")?,
        })
    }

    /// Stable cache fingerprint.
    pub fn fingerprint(&self) -> u64 {
        fingerprint_str(&self.descriptor())
    }
}

/// One tuning scenario: workload × optimizer × domain × budget, optionally
/// seeded from a persisted [`SessionState`].
///
/// # Examples
///
/// ```
/// use patsma::service::{OptimizerSpec, SessionSpec};
///
/// let spec = SessionSpec::synthetic("s0", 48.0, 42)
///     .with_optimizer(OptimizerSpec::NelderMead)
///     .with_budget(1, 12);
/// assert!(spec.validate().is_ok());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSpec {
    /// Report label (no whitespace).
    pub id: String,
    /// What to evaluate.
    pub workload: WorkloadSpec,
    /// Which optimizer drives the session.
    pub optimizer: OptimizerSpec,
    /// Stabilisation iterations per measured candidate (paper §2.3;
    /// a no-op for pure targets, which have nothing to stabilise).
    pub ignore: u32,
    /// Optimizer population size (`num_opt`).
    pub num_opt: usize,
    /// Optimizer iteration budget (`max_iter`).
    pub max_iter: usize,
    /// RNG seed (sessions are exactly reproducible given their seed and
    /// warm-start state).
    pub seed: u64,
    /// Persisted state to warm-start from (`None` = cold start). Must
    /// belong to the same workload fingerprint; optimizers that cannot
    /// consume the snapshot fall back to a cold start.
    pub warm: Option<SessionState>,
    /// What "best" means: the scalarization applied to each candidate's
    /// [`CostVector`]. The default scalar spec reproduces single-objective
    /// behaviour bit-for-bit; non-scalar sessions also report a bounded
    /// Pareto front ([`registry::ParetoRecord`]).
    pub objective: ObjectiveSpec,
}

impl SessionSpec {
    /// A synthetic-landscape session with the default `[1, 128]` integer
    /// domain.
    pub fn synthetic(id: impl Into<String>, optimum: f64, seed: u64) -> Self {
        Self {
            id: id.into(),
            workload: WorkloadSpec::Synthetic {
                optimum,
                dim: 1,
                lo: 1.0,
                hi: 128.0,
                kind: PointKind::Integer,
            },
            optimizer: OptimizerSpec::Csa,
            ignore: 0,
            num_opt: 4,
            max_iter: 8,
            seed,
            warm: None,
            objective: ObjectiveSpec::default(),
        }
    }

    /// A synthetic-landscape session over the same `[1, 128]` box with
    /// exact floating-point candidates (no lattice quantisation).
    pub fn synthetic_float(id: impl Into<String>, optimum: f64, seed: u64) -> Self {
        let mut spec = Self::synthetic(id, optimum, seed);
        if let WorkloadSpec::Synthetic { kind, .. } = &mut spec.workload {
            *kind = PointKind::Float;
        }
        spec
    }

    /// A joint `(schedule kind, chunk)` session over the deterministic
    /// [`synthetic::joint_cost_model`] landscape, chunk domain `[1, 128]`.
    pub fn synthetic_joint(id: impl Into<String>, optimum: f64, seed: u64) -> Self {
        let mut spec = Self::synthetic(id, optimum, seed);
        spec.workload = WorkloadSpec::SyntheticJoint {
            optimum,
            lo: 1,
            hi: 128,
        };
        spec
    }

    /// A session tuning a registry workload (a [`workloads::NAMES`] name)
    /// over its typed [`Workload::space`], measured by wall-clock.
    pub fn named(id: impl Into<String>, workload: impl Into<String>, seed: u64) -> Self {
        let mut spec = Self::synthetic(id, 0.0, seed);
        spec.workload = WorkloadSpec::Named(workload.into());
        spec
    }

    /// A session tuning a registry workload **jointly** over its
    /// `(schedule kind, chunk, …)` space ([`Workload::joint_space`]).
    pub fn named_joint(id: impl Into<String>, workload: impl Into<String>, seed: u64) -> Self {
        let mut spec = Self::synthetic(id, 0.0, seed);
        spec.workload = WorkloadSpec::NamedJoint(workload.into());
        spec
    }

    /// Builder-style optimizer override.
    pub fn with_optimizer(mut self, opt: OptimizerSpec) -> Self {
        self.optimizer = opt;
        self
    }

    /// Builder-style budget override.
    pub fn with_budget(mut self, num_opt: usize, max_iter: usize) -> Self {
        self.num_opt = num_opt;
        self.max_iter = max_iter;
        self
    }

    /// Builder-style objective override: which scalarization of each
    /// candidate's cost vector the session minimises (and, when
    /// non-scalar, whose Pareto front it reports).
    pub fn with_objective(mut self, objective: ObjectiveSpec) -> Self {
        self.objective = objective;
        self
    }

    /// Builder-style warm start: seed the session's optimizer from a
    /// persisted state (see module docs). The state must carry the same
    /// workload fingerprint — [`validate`](Self::validate) rejects the spec
    /// otherwise, because costs from a different landscape would be
    /// meaningless starting material.
    pub fn warm_start(mut self, state: SessionState) -> Self {
        self.warm = Some(state);
        self
    }

    /// Cache fingerprint for this session's evaluations. For measured
    /// (named) workloads the `ignore` protocol changes what a cost *means*
    /// (how many stabilisation iterations precede the measurement), so it
    /// is part of the key; for pure targets `ignore` is a no-op and two
    /// sessions may share entries regardless of it.
    pub fn fingerprint(&self) -> u64 {
        match &self.workload {
            WorkloadSpec::Named(_) | WorkloadSpec::NamedJoint(_) => {
                let mut key = format!("{}/ignore={}", self.workload.descriptor(), self.ignore);
                // Measured workloads cache the *scalarized* cost, so what a
                // cached value means depends on the objective; pure targets
                // cache the raw landscape value and scalarize outside the
                // cache, sharing entries across objectives. Scalar specs
                // skip the segment so pre-objective fingerprints (and
                // persisted states keyed by them) stay stable.
                if !self.objective.is_scalar() {
                    key.push_str("/objective=");
                    key.push_str(&self.objective.descriptor());
                }
                fingerprint_str(&key)
            }
            // Pure landscapes (plain and joint): ignore is a no-op.
            _ => self.workload.fingerprint(),
        }
    }

    /// Check the spec before any session work starts.
    pub fn validate(&self) -> Result<()> {
        if self.id.is_empty() || self.id.chars().any(char::is_whitespace) {
            bail!("session id {:?} must be non-empty and whitespace-free", self.id);
        }
        if self.num_opt == 0 {
            bail!("session {}: num_opt must be >= 1", self.id);
        }
        // Weights can be poked directly into the public field, bypassing
        // the validated `ObjectiveSpec::with_weights` constructor.
        if let Err(e) = self.objective.weights.validate() {
            bail!("session {}: {e}", self.id);
        }
        match &self.workload {
            WorkloadSpec::Synthetic { dim, lo, hi, .. } => {
                if *dim == 0 {
                    bail!("session {}: dim must be >= 1", self.id);
                }
                if !(lo.is_finite() && hi.is_finite() && lo <= hi) {
                    bail!("session {}: bad domain [{lo}, {hi}]", self.id);
                }
            }
            WorkloadSpec::SyntheticJoint { lo, hi, .. } => {
                if *lo < 1 || lo > hi {
                    bail!("session {}: bad joint chunk domain [{lo}, {hi}]", self.id);
                }
                // Surface space-level bound violations (width/magnitude
                // caps) here, before any session work starts, instead of
                // panicking inside run_session's space construction.
                SearchSpace::try_new(vec![
                    Dim::categorical(&Schedule::KINDS),
                    Dim::Int { lo: *lo, hi: *hi },
                ])
                .with_context(|| format!("session {}: joint chunk domain", self.id))?;
            }
            WorkloadSpec::Named(name) | WorkloadSpec::NamedJoint(name) => {
                if !workloads::NAMES.contains(&name.as_str()) {
                    bail!(
                        "session {}: unknown workload {name:?}; known: {:?}",
                        self.id,
                        workloads::NAMES
                    );
                }
            }
        }
        if let Some(ws) = &self.warm {
            if ws.fingerprint != self.fingerprint() {
                bail!(
                    "session {}: warm-start state belongs to a different landscape \
                     (state fingerprint {}, session fingerprint {})",
                    self.id,
                    ws.fingerprint,
                    self.fingerprint()
                );
            }
        }
        Ok(())
    }
}

/// Instantiated evaluation target.
enum Target {
    /// Deterministic closed-form landscape.
    Pure(PureCost),
    /// Stateful workload measured by wall-clock at decoded typed cells of
    /// `space` (the workload's plain or joint surface).
    Measured {
        /// The constructed workload instance.
        workload: Box<dyn Workload>,
        /// The typed space cache keys decode through
        /// ([`Workload::space`] / [`Workload::joint_space`]).
        space: SearchSpace,
    },
}

/// Which closed-form landscape a pure target evaluates (cheap to copy into
/// parallel batch evaluations).
#[derive(Clone, Copy)]
enum PureCost {
    /// [`pure_cost`]: the chunk-cost model summed over dimensions.
    Sum {
        /// Per-coordinate cost minimum.
        optimum: f64,
    },
    /// [`synthetic::joint_cost_model`] over a decoded `(kind, chunk)` cell.
    Joint {
        /// Chunk location of the dynamic-kind minimum.
        optimum: f64,
    },
}

impl PureCost {
    /// Evaluate the landscape on a cache-key point.
    fn eval(self, point: &[f64]) -> f64 {
        match self {
            PureCost::Sum { optimum } => pure_cost(point, optimum),
            PureCost::Joint { optimum } => {
                synthetic::joint_cost_model(point[0] as usize, point[1], optimum)
            }
        }
    }
}

/// How a session's internal candidates map onto user-domain cache keys.
enum Domain {
    /// Per-dimension numeric box with a single [`PointKind`].
    Box {
        /// Lower bounds.
        lo: Vec<f64>,
        /// Upper bounds.
        hi: Vec<f64>,
        /// Lattice-quantised or exact-float candidates.
        kind: PointKind,
    },
    /// Typed search space: the cache key is the decoded cell's
    /// [`crate::space::Point::key`], so two cells that differ only in a
    /// categorical coordinate never collide.
    Typed(SearchSpace),
}

impl Domain {
    /// Map one internal-domain candidate onto the exact user-domain values
    /// the application is handed — this vector *is* the cache key.
    fn key(&self, internal: &[f64]) -> Vec<f64> {
        match self {
            Domain::Box { lo, hi, kind } => quantize_candidate(internal, lo, hi, *kind),
            Domain::Typed(space) => space.decode_internal(internal).key(),
        }
    }

    /// Typed rendering of a best point (`None` for box domains).
    fn label(&self, key: &[f64]) -> Option<String> {
        match self {
            Domain::Box { .. } => None,
            Domain::Typed(space) => Some(space.label(&space.point_from_key(key))),
        }
    }
}

/// What the retune planner decided for a registry's persisted states.
#[derive(Debug, Clone, PartialEq)]
pub struct RetunePlan {
    /// Sessions to re-run (warm-started, reduced budget), state order.
    pub specs: Vec<SessionSpec>,
    /// Ids being re-tuned (environment drifted, or `force`).
    pub drifted: Vec<String>,
    /// Ids left untouched (same environment, results still valid).
    pub fresh: Vec<String>,
}

/// Decide which persisted sessions need re-tuning under the `env`
/// environment. A session whose state was captured under a different
/// environment fingerprint (thread-count change, OS change) gets a
/// warm-started spec with `budget_pct` percent of its original `max_iter`
/// (min 2 — a warm start needs at least the re-measure + one refinement
/// iteration); sessions whose environment is unchanged are reported as
/// fresh and skipped. `force` re-tunes everything regardless of drift.
///
/// # Examples
///
/// ```
/// use patsma::service::{plan_retune, EnvFingerprint};
///
/// let plan = plan_retune(&[], &EnvFingerprint::current(), 50, false).unwrap();
/// assert!(plan.specs.is_empty() && plan.drifted.is_empty());
/// ```
pub fn plan_retune(
    states: &[SessionState],
    env: &EnvFingerprint,
    budget_pct: u32,
    force: bool,
) -> Result<RetunePlan> {
    let mut plan = RetunePlan {
        specs: Vec::new(),
        drifted: Vec::new(),
        fresh: Vec::new(),
    };
    for st in states {
        if !force && !env.drifted_from(&st.env) {
            plan.fresh.push(st.id.clone());
            continue;
        }
        let workload = WorkloadSpec::parse_descriptor(&st.workload)
            .with_context(|| format!("state {}", st.id))?;
        let optimizer = OptimizerSpec::parse(&st.optimizer)
            .with_context(|| format!("state {}", st.id))?;
        let max_iter = (st.max_iter.saturating_mul(budget_pct as usize) / 100).max(2);
        // Non-scalar sessions persist their objective descriptor as a state
        // extra; reconstructing it here keeps the warm fingerprint valid.
        let objective = match st.extra.iter().find(|(k, _)| k == "objective") {
            Some((_, d)) => ObjectiveSpec::parse_descriptor(d)
                .map_err(|e| anyhow::anyhow!("state {}: {e}", st.id))?,
            None => ObjectiveSpec::default(),
        };
        let spec = SessionSpec {
            id: st.id.clone(),
            workload,
            optimizer,
            ignore: st.ignore,
            num_opt: st.num_opt,
            max_iter,
            seed: st.seed,
            objective,
            warm: Some(st.clone()),
        };
        spec.validate().with_context(|| format!("state {}", st.id))?;
        plan.drifted.push(st.id.clone());
        plan.specs.push(spec);
    }
    Ok(plan)
}

/// The concurrent tuning runtime (see module docs).
///
/// # Examples
///
/// ```
/// use patsma::service::{SessionSpec, TuningService};
///
/// let service = TuningService::new(2);
/// let report = service.run(&[SessionSpec::synthetic("s", 24.0, 9)]).unwrap();
/// assert_eq!(report.sessions[0].id, "s");
/// ```
pub struct TuningService {
    pool: ThreadPool,
    cache: PointCache,
    history: Mutex<Vec<SessionReport>>,
    sessions: ShardedSessions,
    /// Converged cells keyed by execution context — what `lookup` answers
    /// from and `promote` merges into; persisted as `table` records.
    table: SharedTunedTable,
    /// Registry record lines from newer writers, carried through snapshots
    /// verbatim (forward compatibility).
    extras: Mutex<Vec<String>>,
    /// Latest Pareto front per session id (non-scalar objectives only),
    /// flattened into `pareto` registry records on every report.
    fronts: Mutex<BTreeMap<String, Vec<registry::ParetoRecord>>>,
    draining: AtomicBool,
}

impl TuningService {
    /// A service running at most `concurrency` sessions at once (0 is
    /// promoted to 1, like [`ThreadPool::new`]), with the default shard
    /// count and cache cap.
    pub fn new(concurrency: usize) -> Self {
        Self::with_options(concurrency, DEFAULT_SHARDS, DEFAULT_CACHE_CAP)
    }

    /// A service with explicit session-map shard count and point-cache
    /// residency cap (what `patsma daemon start --shards --cache-cap`
    /// constructs).
    pub fn with_options(concurrency: usize, shards: usize, cache_cap: usize) -> Self {
        Self {
            pool: ThreadPool::new(concurrency),
            cache: PointCache::with_cap(cache_cap),
            history: Mutex::new(Vec::new()),
            sessions: ShardedSessions::new(shards, EnvFingerprint::current().hash),
            table: SharedTunedTable::new(),
            extras: Mutex::new(Vec::new()),
            fronts: Mutex::new(BTreeMap::new()),
            draining: AtomicBool::new(false),
        }
    }

    /// The shared tuned table — regions running in-process can hold the
    /// same handle the daemon serves `lookup`/`promote` from.
    pub fn table(&self) -> &SharedTunedTable {
        &self.table
    }

    /// Session-level parallelism bound.
    pub fn concurrency(&self) -> usize {
        self.pool.threads()
    }

    /// Shared-cache counter snapshot.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Run a batch of sessions concurrently (bounded by
    /// [`concurrency`](Self::concurrency)) and return their reports and
    /// persisted states in spec order. Results also accumulate into the
    /// service's registry for [`report`](Self::report) (per session id,
    /// the latest state wins).
    pub fn run(&self, specs: &[SessionSpec]) -> Result<ServiceReport> {
        for spec in specs {
            spec.validate()?;
        }
        let outcomes: Vec<SessionOutcome> = if specs.len() <= 1 {
            // A lone session keeps the caller thread out of a pool region,
            // so its pure batch evaluations can parallelise on the pool.
            specs
                .iter()
                .map(|s| run_session(s, &self.cache, &self.pool))
                .collect()
        } else {
            let slots: Vec<Mutex<Option<SessionOutcome>>> =
                specs.iter().map(|_| Mutex::new(None)).collect();
            let par = self.pool.exec(0, specs.len()).sched(Schedule::Dynamic(1));
            par.run_indexed(|i| {
                let outcome = run_session(&specs[i], &self.cache, &self.pool);
                *slots[i].lock().unwrap() = Some(outcome);
            });
            slots
                .into_iter()
                .map(|slot| slot.into_inner().unwrap().expect("session completed"))
                .collect()
        };
        let sessions: Vec<SessionReport> = outcomes.iter().map(|o| o.report.clone()).collect();
        let mut batch_states: Vec<SessionState> = Vec::new();
        for (spec, outcome) in specs.iter().zip(outcomes) {
            if let Some(st) = &outcome.state {
                batch_states.push(st.clone());
            }
            if !outcome.front.is_empty() {
                let records = outcome
                    .front
                    .entries()
                    .iter()
                    .map(|e| registry::ParetoRecord::from_entry(&spec.id, e))
                    .collect();
                self.fronts.lock().unwrap().insert(spec.id.clone(), records);
            }
            // Completed sessions answer later matching requests without a
            // re-run (the daemon's converged read fast path).
            self.sessions.insert(SessionEntry {
                report: outcome.report,
                state: outcome.state,
                fingerprint: spec.fingerprint(),
                converged: true,
            });
        }
        self.history.lock().unwrap().extend(sessions.iter().cloned());
        Ok(ServiceReport {
            sessions,
            states: batch_states,
            cache: self.cache.stats(),
            table: self.table.entries(),
            pareto: self.pareto_records(),
            extras: self.extras.lock().unwrap().clone(),
        })
    }

    /// The latest persisted Pareto records, flattened in session-id order.
    fn pareto_records(&self) -> Vec<registry::ParetoRecord> {
        self.fronts
            .lock()
            .unwrap()
            .values()
            .flat_map(|records| records.iter().cloned())
            .collect()
    }

    /// Everything this service has run so far, with current cache counters
    /// — the registry the coordinator and CLI consume. Sessions are in run
    /// order (every run, including re-runs); states dedupe by id (latest
    /// wins) and come back sorted by id.
    pub fn report(&self) -> ServiceReport {
        let (_, states) = self.sessions.snapshot();
        ServiceReport {
            sessions: self.history.lock().unwrap().clone(),
            states,
            cache: self.cache.stats(),
            table: self.table.entries(),
            pareto: self.pareto_records(),
            extras: self.extras.lock().unwrap().clone(),
        }
    }

    /// The *compacted* registry the daemon persists: one session report per
    /// id (the latest), its state, current cache counters — what survives
    /// a snapshot/restart cycle, as opposed to [`report`](Self::report)'s
    /// full in-memory history.
    pub fn registry_snapshot(&self) -> ServiceReport {
        let (sessions, states) = self.sessions.snapshot();
        ServiceReport {
            sessions,
            states,
            cache: self.cache.stats(),
            table: self.table.entries(),
            pareto: self.pareto_records(),
            extras: self.extras.lock().unwrap().clone(),
        }
    }

    /// Drop all but the latest history entry per session id (what the
    /// daemon's background compaction thread runs periodically so a
    /// long-lived process does not accumulate unbounded re-run history).
    /// Returns how many entries were dropped; run order is preserved.
    pub fn compact_history(&self) -> usize {
        let mut history = self.history.lock().unwrap();
        let before = history.len();
        let mut seen = std::collections::HashSet::new();
        let mut kept: Vec<SessionReport> = Vec::new();
        for report in history.drain(..).rev() {
            if seen.insert(report.id.clone()) {
                kept.push(report);
            }
        }
        kept.reverse();
        *history = kept;
        before - history.len()
    }

    /// Seed the service from a previously persisted registry (what the
    /// daemon does on startup). Loaded sessions count as converged: a
    /// matching `tune` request is answered from state without a re-run.
    pub fn seed_from(&self, report: &ServiceReport) {
        self.sessions.load(&report.sessions, &report.states);
        self.history
            .lock()
            .unwrap()
            .extend(report.sessions.iter().cloned());
        self.table.load(&report.table);
        if !report.pareto.is_empty() {
            // Latest front wins per session id, like session states.
            let mut incoming: BTreeMap<String, Vec<registry::ParetoRecord>> = BTreeMap::new();
            for p in &report.pareto {
                incoming.entry(p.session.clone()).or_default().push(p.clone());
            }
            self.fronts.lock().unwrap().extend(incoming);
        }
        self.extras
            .lock()
            .unwrap()
            .extend(report.extras.iter().cloned());
    }

    /// Refuse new sessions from now on (in-flight ones finish). Used by
    /// the daemon's graceful SIGTERM drain; there is no un-drain.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Whether [`begin_drain`](Self::begin_drain) has been called.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// How many requests were answered from a converged session without a
    /// tuning run.
    pub fn fast_hits(&self) -> u64 {
        self.sessions.fast_hits()
    }

    /// The single typed API the whole runtime speaks — both the in-process
    /// service and the daemon wire protocol route every operation through
    /// here (the 0.7 redesign of the ad-hoc `run`/`report`/`retune`
    /// surface; those remain as conveniences over the same state).
    ///
    /// # Examples
    ///
    /// ```
    /// use patsma::service::{Request, Response, SessionSpec, TuningService};
    ///
    /// let service = TuningService::new(1);
    /// let spec = SessionSpec::synthetic("h", 48.0, 7);
    /// match service.handle(Request::Tune { spec, fresh: false }) {
    ///     Response::Session { report, cached } => {
    ///         assert_eq!(report.id, "h");
    ///         assert!(!cached, "first run is never cached");
    ///     }
    ///     other => panic!("unexpected {other:?}"),
    /// }
    /// ```
    pub fn handle(&self, request: Request) -> Response {
        match request {
            Request::Ping => Response::Pong {
                version: proto::PROTO_VERSION,
                sessions: self.sessions.len(),
                draining: self.is_draining(),
            },
            Request::Report => Response::Report(self.report()),
            Request::Shutdown => {
                self.begin_drain();
                Response::Draining
            }
            Request::Tune { mut spec, fresh } => {
                if self.is_draining() {
                    return Response::Draining;
                }
                if let Err(e) = spec.validate() {
                    return Response::Error(format!("{e:#}"));
                }
                let fingerprint = spec.fingerprint();
                if !fresh {
                    if let Some(entry) = self.sessions.get(fingerprint, &spec.id) {
                        // Converged over the same landscape: answer from
                        // state — a read, not a tuning run.
                        if entry.converged && entry.fingerprint == fingerprint {
                            return Response::Session {
                                report: entry.report.clone(),
                                cached: true,
                            };
                        }
                        // Otherwise warm-start when the persisted state
                        // still belongs to this landscape.
                        if let Some(state) = &entry.state {
                            if state.fingerprint == fingerprint {
                                spec.warm = Some(state.clone());
                            }
                        }
                    }
                }
                match self.run(std::slice::from_ref(&spec)) {
                    Ok(report) => Response::Session {
                        report: report.sessions[0].clone(),
                        cached: false,
                    },
                    Err(e) => Response::Error(format!("{e:#}")),
                }
            }
            // A table lookup is a read — still answered while draining, so
            // clients racing a shutdown keep their bypass hits.
            Request::Lookup { key } => match self.table.lookup(&key) {
                TableHit::Exact(cell) => Response::Cell {
                    entry: Some(TableEntry { key, cell }),
                    exact: true,
                },
                TableHit::Near(near_key, cell) => Response::Cell {
                    entry: Some(TableEntry { key: near_key, cell }),
                    exact: false,
                },
                TableHit::Miss => Response::Cell {
                    entry: None,
                    exact: false,
                },
            },
            Request::Promote { entry } => {
                if self.is_draining() {
                    // A promote mutates state the drain is about to
                    // snapshot; refuse it like any other write.
                    return Response::Draining;
                }
                match self.table.promote(entry) {
                    Ok(weight) => Response::Promoted { weight },
                    Err(e) => Response::Error(format!("{e}")),
                }
            }
            Request::Retune { budget, force } => {
                if self.is_draining() {
                    return Response::Draining;
                }
                let (_, states) = self.sessions.snapshot();
                let plan =
                    match plan_retune(&states, &EnvFingerprint::current(), budget, force) {
                        Ok(p) => p,
                        Err(e) => return Response::Error(format!("{e:#}")),
                    };
                if let Err(e) = self.run(&plan.specs) {
                    return Response::Error(format!("{e:#}"));
                }
                Response::Retuned {
                    drifted: plan.drifted,
                    fresh: plan.fresh,
                }
            }
        }
    }
}

/// Map one internal-domain candidate onto the exact user-domain values the
/// application is handed — integer-lattice quantised or clamped float,
/// per the domain's [`PointKind`]. This vector *is* the cache key.
fn quantize_candidate(internal: &[f64], lo: &[f64], hi: &[f64], kind: PointKind) -> Vec<f64> {
    internal
        .iter()
        .enumerate()
        .map(|(d, &x)| {
            let raw = rescale_internal(x, lo[d], hi[d]);
            match kind {
                PointKind::Integer => quantize_integer(raw, lo[d], hi[d]),
                PointKind::Float => raw.clamp(lo[d], hi[d]),
            }
        })
        .collect()
}

/// One completed session: its report plus (if the optimizer supports
/// persistence) the state a later run can warm-start from.
struct SessionOutcome {
    report: SessionReport,
    state: Option<SessionState>,
    /// Non-dominated cells under a non-scalar objective (empty — and never
    /// offered to — for the scalar default).
    front: ParetoFront,
}

/// Drive one session to completion: pull candidate batches from the
/// optimizer, evaluate them (cache-aware; in parallel for pure targets when
/// not already inside a pool region), feed the costs back.
fn run_session(spec: &SessionSpec, cache: &PointCache, pool: &ThreadPool) -> SessionOutcome {
    let t0 = Instant::now();
    let (mut target, dim, domain) = match &spec.workload {
        WorkloadSpec::Synthetic {
            optimum,
            dim,
            lo,
            hi,
            kind,
        } => (
            Target::Pure(PureCost::Sum { optimum: *optimum }),
            *dim,
            Domain::Box {
                lo: vec![*lo; *dim],
                hi: vec![*hi; *dim],
                kind: *kind,
            },
        ),
        WorkloadSpec::SyntheticJoint { optimum, .. } => (
            Target::Pure(PureCost::Joint { optimum: *optimum }),
            2,
            Domain::Typed(spec.workload.space().expect("joint workload has a space")),
        ),
        WorkloadSpec::Named(name) => {
            let w = workloads::by_name(name).expect("validated workload name");
            let space = w.space();
            let dim = space.dim();
            (
                Target::Measured {
                    workload: w,
                    space: space.clone(),
                },
                dim,
                Domain::Typed(space),
            )
        }
        WorkloadSpec::NamedJoint(name) => {
            let w = workloads::by_name(name).expect("validated workload name");
            let space = w.joint_space();
            let dim = space.dim();
            (
                Target::Measured {
                    workload: w,
                    space: space.clone(),
                },
                dim,
                Domain::Typed(space),
            )
        }
    };
    let fingerprint = spec.fingerprint();
    let mut opt = spec
        .optimizer
        .build(dim, spec.num_opt, spec.max_iter, spec.seed);
    // Seed from persisted state when present; optimizers that cannot
    // consume the snapshot leave `warm_started` false and run cold.
    let warm_started = spec
        .warm
        .as_ref()
        .map(|ws| opt.warm_start(&ws.opt_state))
        .unwrap_or(false);

    // Non-scalar sessions accumulate a Pareto front over cache *misses*;
    // the scalar default constructs nothing and keeps the seed's exact
    // single-objective cost path.
    let cores = pool.threads().max(1);
    let mut mo = (!spec.objective.is_scalar()).then(|| MultiObjective::new(spec.objective));

    let mut best: Option<(Vec<f64>, f64)> = None;
    let mut cache_hits = 0u64;
    let mut cache_misses = 0u64;
    let mut target_iterations = 0u64;
    let mut costs: Vec<f64> = Vec::new();

    loop {
        let batch = opt.run_batch(&costs);
        if batch.is_empty() {
            break;
        }
        let points: Vec<Vec<f64>> = batch.iter().map(|cand| domain.key(cand)).collect();
        let mut hit_flags = vec![false; points.len()];
        // Measured-target cost vectors captured alongside the scalarized
        // cache value (filled only on misses of non-scalar sessions; pure
        // targets derive theirs from the raw landscape value instead).
        let mut vectors: Vec<Option<CostVector>> = Vec::new();
        costs = match &mut target {
            Target::Pure(pure) => {
                let pure = *pure;
                let slots: Vec<Mutex<(f64, bool)>> =
                    points.iter().map(|_| Mutex::new((0.0, false))).collect();
                let par = pool.exec(0, points.len()).sched(Schedule::Dynamic(1));
                par.run_indexed(|i| {
                    let (cost, hit) = cache.get_or_compute(fingerprint, &points[i], || {
                        pure.eval(&points[i])
                    });
                    *slots[i].lock().unwrap() = (cost, hit);
                });
                slots
                    .into_iter()
                    .enumerate()
                    .map(|(i, slot)| {
                        let (cost, hit) = slot.into_inner().unwrap();
                        hit_flags[i] = hit;
                        cost
                    })
                    .collect()
            }
            Target::Measured { workload, space } => points
                .iter()
                .enumerate()
                .map(|(i, point)| {
                    let mut vector: Option<CostVector> = None;
                    let (cost, hit) = cache.get_or_compute(fingerprint, point, || {
                        // Exact inverse for keys produced by decoding this
                        // space — the cell the application is handed *is*
                        // the cache key (typed, kind included).
                        let typed = space.point_from_key(point);
                        if mo.is_some() {
                            // Non-scalar sessions keep *every* stabilisation
                            // sample: the spread across the `ignore + 1`
                            // runs is the p95 signal. The cached value is
                            // the scalarized cost (the fingerprint already
                            // carries the objective descriptor).
                            let mut samples = Vec::with_capacity(spec.ignore + 1);
                            for _ in 0..=spec.ignore {
                                let t = Instant::now();
                                let _ = workload.run_point(&typed);
                                // Coarse timers report 0 for tiny cells;
                                // clamp so the vector stays positive.
                                samples
                                    .push(t.elapsed().as_secs_f64().max(f64::MIN_POSITIVE));
                            }
                            let v = CostVector::from_samples(&samples, 1.0, cores)
                                .expect("clamped samples are finite and positive");
                            vector = Some(v);
                            spec.objective.scalarize(&v)
                        } else {
                            // The ignore protocol (§2.3): run `ignore`
                            // stabilisation iterations, measure the last one.
                            let mut measured = 0.0;
                            for _ in 0..=spec.ignore {
                                let t = Instant::now();
                                let _ = workload.run_point(&typed);
                                measured = t.elapsed().as_secs_f64();
                            }
                            measured
                        }
                    });
                    vectors.push(vector);
                    hit_flags[i] = hit;
                    cost
                })
                .collect(),
        };
        // Sequential, index-ordered bookkeeping keeps the session report
        // deterministic regardless of evaluation interleaving.
        for (i, point) in points.iter().enumerate() {
            if hit_flags[i] {
                cache_hits += 1;
            } else {
                cache_misses += 1;
                target_iterations += match &target {
                    // Pure targets evaluate once; there is nothing to
                    // stabilise, so `ignore` adds no iterations.
                    Target::Pure(_) => 1,
                    Target::Measured { .. } => (spec.ignore as u64) + 1,
                };
            }
            if let Some(mo) = &mut mo {
                match &target {
                    // Pure landscapes cache the *raw* value (shared across
                    // objectives); scalarize outside the cache and offer
                    // fresh evaluations to the front.
                    Target::Pure(_) => {
                        let vector = CostVector::from_scalar(costs[i]);
                        costs[i] = if hit_flags[i] {
                            spec.objective.scalarize(&vector)
                        } else {
                            mo.observe(point.clone(), domain.label(point), vector)
                        };
                    }
                    // Measured values are cached already-scalarized; only a
                    // fresh measurement carries a vector to offer.
                    Target::Measured { .. } => {
                        if let Some(v) = vectors.get(i).copied().flatten() {
                            mo.observe(point.clone(), domain.label(point), v);
                        }
                    }
                }
            }
            let cost = costs[i];
            if best.as_ref().map(|(_, c)| cost < *c).unwrap_or(true) {
                best = Some((point.clone(), cost));
            }
        }
    }

    let (best_point, best_cost) = best.unwrap_or((vec![0.0; dim], f64::INFINITY));
    // Typed domains carry their decoded cell into the registry (categorical
    // values by name), e.g. `dynamic,32`.
    let best_label = domain.label(&best_point);
    // A warm-started (retuned) session ran at a *reduced* budget; the state
    // it persists must carry the scenario's original budget forward, or
    // each successive retune would re-apply its percentage to an already
    // reduced value and grind every budget down to the floor of 2.
    let full_max_iter = spec
        .warm
        .as_ref()
        .map(|ws| ws.max_iter.max(spec.max_iter))
        .unwrap_or(spec.max_iter);
    let state = opt.export_state().map(|opt_state| SessionState {
        id: spec.id.clone(),
        workload: spec.workload.descriptor(),
        fingerprint,
        env: EnvFingerprint::current(),
        optimizer: spec.optimizer.name().to_string(),
        num_opt: spec.num_opt,
        max_iter: full_max_iter,
        seed: spec.seed,
        ignore: spec.ignore,
        best_point: best_point.clone(),
        best_cost,
        opt_state,
        // The objective descriptor rides along so `plan_retune` can rebuild
        // the spec (and its fingerprint) from persisted state alone.
        extra: if spec.objective.is_scalar() {
            Vec::new()
        } else {
            vec![("objective".to_string(), spec.objective.descriptor())]
        },
    });
    SessionOutcome {
        report: SessionReport {
            id: spec.id.clone(),
            workload: spec.workload.descriptor(),
            optimizer: opt.name().to_string(),
            evaluations: opt.evaluations(),
            target_iterations,
            cache_hits,
            cache_misses,
            best_point,
            best_label,
            best_cost,
            wall_secs: t0.elapsed().as_secs_f64(),
            warm_started,
            extra: Vec::new(),
        },
        state,
        front: mo
            .map(|m| m.front().clone())
            .unwrap_or_else(|| ParetoFront::new(1)),
    }
}

/// The deterministic session landscape: the chunk-cost model summed over
/// dimensions (minimum at `optimum` per coordinate).
fn pure_cost(point: &[f64], optimum: f64) -> f64 {
    point
        .iter()
        .map(|&p| synthetic::chunk_cost_model(p, optimum))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizer_spec_parse_roundtrip() {
        for s in ["csa", "nm", "sa", "random", "pso", "grid"] {
            let spec = OptimizerSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s);
        }
        assert!(OptimizerSpec::parse("bogus").is_err());
    }

    #[test]
    fn workload_descriptors_are_distinct_and_clean() {
        let a = WorkloadSpec::Synthetic {
            optimum: 48.0,
            dim: 1,
            lo: 1.0,
            hi: 128.0,
            kind: PointKind::Integer,
        };
        let b = WorkloadSpec::Synthetic {
            optimum: 24.0,
            dim: 1,
            lo: 1.0,
            hi: 128.0,
            kind: PointKind::Integer,
        };
        let c = WorkloadSpec::Named("spmv".into());
        let mut d = a.clone();
        if let WorkloadSpec::Synthetic { kind, .. } = &mut d {
            *kind = PointKind::Float;
        }
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        // Point kind is part of the landscape identity: an integer-lattice
        // session and a float session must not share cache entries.
        assert_ne!(a.fingerprint(), d.fingerprint());
        for w in [a, b, c, d] {
            assert!(!w.descriptor().contains(char::is_whitespace));
        }
    }

    #[test]
    fn descriptor_parse_roundtrip() {
        let specs = [
            WorkloadSpec::Synthetic {
                optimum: 48.5,
                dim: 2,
                lo: 1.0,
                hi: 128.0,
                kind: PointKind::Float,
            },
            WorkloadSpec::Synthetic {
                optimum: 24.0,
                dim: 1,
                lo: 1.0,
                hi: 64.0,
                kind: PointKind::Integer,
            },
            WorkloadSpec::Named("spmv".into()),
        ];
        for w in specs {
            let d = w.descriptor();
            let parsed = WorkloadSpec::parse_descriptor(&d).unwrap();
            assert_eq!(parsed, w, "{d}");
            assert_eq!(parsed.descriptor(), d, "round trip must be exact");
        }
        // Unknown segments are ignored (forward compatibility).
        let fwd = WorkloadSpec::parse_descriptor(
            "synthetic/opt=48/dim=1/lo=1/hi=128/kind=int/future=stuff",
        )
        .unwrap();
        assert_eq!(
            fwd.descriptor(),
            "synthetic/opt=48/dim=1/lo=1/hi=128/kind=int"
        );
        assert!(WorkloadSpec::parse_descriptor("garbage").is_err());
        assert!(WorkloadSpec::parse_descriptor("synthetic/opt=48").is_err());
    }

    #[test]
    fn named_session_fingerprint_depends_on_ignore() {
        // The ignore protocol changes what a measured cost means, so two
        // sessions over one named workload with different `ignore` must not
        // share cache entries; for pure targets ignore is a no-op and they
        // must share.
        let mut a = SessionSpec::synthetic("a", 48.0, 1);
        a.workload = WorkloadSpec::Named("spmv".into());
        let mut b = a.clone();
        b.ignore = 3;
        assert_ne!(a.fingerprint(), b.fingerprint());

        let p = SessionSpec::synthetic("p", 48.0, 1);
        let mut q = p.clone();
        q.ignore = 3;
        assert_eq!(p.fingerprint(), q.fingerprint());
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let mut s = SessionSpec::synthetic("ok", 48.0, 1);
        s.validate().unwrap();
        s.id = "has space".into();
        assert!(s.validate().is_err());
        s.id = "ok".into();
        s.num_opt = 0;
        assert!(s.validate().is_err());
        s.num_opt = 4;
        s.workload = WorkloadSpec::Named("nope".into());
        assert!(s.validate().is_err());
        s.workload = WorkloadSpec::Synthetic {
            optimum: 1.0,
            dim: 0,
            lo: 1.0,
            hi: 2.0,
            kind: PointKind::Integer,
        };
        assert!(s.validate().is_err());
        s.workload = WorkloadSpec::Synthetic {
            optimum: 1.0,
            dim: 1,
            lo: 5.0,
            hi: 2.0,
            kind: PointKind::Integer,
        };
        assert!(s.validate().is_err());
        // Joint domains: ordering and the space-level width cap are both
        // rejected at validate time, not at session start.
        s.workload = WorkloadSpec::SyntheticJoint {
            optimum: 1.0,
            lo: 9,
            hi: 2,
        };
        assert!(s.validate().is_err());
        s.workload = WorkloadSpec::SyntheticJoint {
            optimum: 1.0,
            lo: 1,
            hi: 1 << 40,
        };
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_rejects_cross_landscape_warm_start() {
        let service = TuningService::new(1);
        let donor = SessionSpec::synthetic("donor", 48.0, 7).with_budget(4, 6);
        let report = service.run(std::slice::from_ref(&donor)).unwrap();
        let state = report.states[0].clone();

        // Same landscape: accepted.
        SessionSpec::synthetic("same", 48.0, 8)
            .warm_start(state.clone())
            .validate()
            .unwrap();
        // Different optimum ⇒ different fingerprint ⇒ rejected.
        assert!(SessionSpec::synthetic("other", 24.0, 8)
            .warm_start(state)
            .validate()
            .is_err());
    }

    #[test]
    fn quantize_candidate_respects_point_kind() {
        let (lo, hi) = (vec![1.0], vec![64.0]);
        // An internal coordinate that rescales to 32.75.
        let internal = [(32.75 - 1.0) / (64.0 - 1.0) * 2.0 - 1.0];
        let int_point = quantize_candidate(&internal, &lo, &hi, PointKind::Integer);
        let float_point = quantize_candidate(&internal, &lo, &hi, PointKind::Float);
        assert_eq!(int_point, vec![33.0], "integer domains round to lattice");
        assert!(
            (float_point[0] - 32.75).abs() < 1e-12,
            "float domains keep the exact value: {float_point:?}"
        );
    }

    #[test]
    fn float_sessions_cache_distinct_candidates_separately() {
        // The fix for the float-domain collapse: distinct float candidates
        // must evaluate independently. A float CSA session proposes many
        // sub-integer candidates; if they collapsed onto the integer
        // lattice the cache would claim ~1 entry per lattice point.
        let service = TuningService::new(1);
        let spec = SessionSpec::synthetic_float("float", 48.5, 5).with_budget(4, 10);
        let report = service.run(&[spec]).unwrap();
        let s = &report.sessions[0];
        assert_eq!(s.evaluations, 40);
        // Float candidates essentially never repeat bit-for-bit, so misses
        // dominate: far more distinct entries than the 1–2 lattice cells
        // the old i64 key would have produced around the optimum.
        assert!(
            report.cache.entries > 20,
            "float candidates collapsed: {:?}",
            report.cache
        );
        assert!(s.best_cost.is_finite());
        assert!((1.0..=128.0).contains(&s.best_point[0]));
    }

    #[test]
    fn named_joint_descriptor_roundtrip_and_distinct_fingerprints() {
        let spec = SessionSpec::named_joint("nj", "spmv", 1);
        assert_eq!(spec.workload.descriptor(), "named-joint/spmv");
        assert_eq!(
            WorkloadSpec::parse_descriptor("named-joint/spmv").unwrap(),
            spec.workload
        );
        spec.validate().unwrap();
        // Unknown registry names are rejected up front, like plain Named.
        let bad = SessionSpec::named_joint("bad", "nope", 1);
        assert!(bad.validate().is_err());
        // Joint and plain sessions over one workload never share cache
        // entries, and the ignore protocol is part of both identities.
        let plain = SessionSpec::named("n", "spmv", 1);
        plain.validate().unwrap();
        assert_ne!(spec.fingerprint(), plain.fingerprint());
        let mut slow = spec.clone();
        slow.ignore = 2;
        assert_ne!(spec.fingerprint(), slow.fingerprint());
        assert!(WorkloadSpec::parse_descriptor("named-joint/").is_err());
    }

    #[test]
    fn joint_descriptor_roundtrip_and_distinct_fingerprints() {
        let joint = WorkloadSpec::SyntheticJoint {
            optimum: 48.0,
            lo: 1,
            hi: 128,
        };
        let d = joint.descriptor();
        assert_eq!(d, "synthetic-joint/opt=48/lo=1/hi=128");
        assert_eq!(WorkloadSpec::parse_descriptor(&d).unwrap(), joint);
        // A joint landscape never shares cache entries with the plain
        // synthetic one over the same numbers.
        let plain = WorkloadSpec::Synthetic {
            optimum: 48.0,
            dim: 1,
            lo: 1.0,
            hi: 128.0,
            kind: PointKind::Integer,
        };
        assert_ne!(joint.fingerprint(), plain.fingerprint());
        assert!(joint.space().is_some());
        assert!(plain.space().is_none());
    }

    #[test]
    fn joint_session_runs_and_labels_its_best_cell() {
        let service = TuningService::new(1);
        let spec = SessionSpec::synthetic_joint("joint", 48.0, 7).with_budget(5, 16);
        let report = service.run(&[spec]).unwrap();
        let s = &report.sessions[0];
        assert_eq!(s.evaluations, 80);
        assert_eq!(s.best_point.len(), 2, "(kind, chunk)");
        let label = s.best_label.as_deref().expect("typed session has a label");
        let kind = label.split(',').next().unwrap();
        assert!(
            Schedule::KINDS.iter().any(|k| *k == kind),
            "label {label:?} must start with a schedule kind"
        );
        // The kind coordinate is a valid bin, the chunk is in-domain.
        assert!((0.0..4.0).contains(&s.best_point[0]));
        assert!((1.0..=128.0).contains(&s.best_point[1]));
        // CSA probes the centre cell (dynamic, mid-chunk) first, whose
        // joint cost is strictly below the flat static penalty — so the
        // best cell can never be the static kind's ceiling.
        assert!(s.best_cost < 1.9, "best {label:?} at {}", s.best_cost);
    }

    #[test]
    fn joint_cells_differing_only_in_kind_do_not_collide() {
        // dynamic,chunk=32 vs guided,chunk=32: same chunk, different cell.
        let cache = PointCache::new();
        let spec = SessionSpec::synthetic_joint("k", 32.0, 1);
        let space = spec.workload.space().unwrap();
        let fp = spec.fingerprint();
        let dynamic = space.point_from_key(&[2.0, 32.0]);
        let guided = space.point_from_key(&[3.0, 32.0]);
        let (_, h1) = cache.get_or_compute(fp, &dynamic.key(), || 1.0);
        let (c2, h2) = cache.get_or_compute(fp, &guided.key(), || 2.0);
        assert!(!h1);
        assert!(!h2, "kind must be part of the cache key");
        assert_eq!(c2, 2.0);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn single_session_finds_the_synthetic_optimum_region() {
        let service = TuningService::new(2);
        let spec = SessionSpec::synthetic("solo", 48.0, 7).with_budget(5, 20);
        let report = service.run(std::slice::from_ref(&spec)).unwrap();
        let s = &report.sessions[0];
        assert_eq!(s.id, "solo");
        assert_eq!(s.optimizer, "csa");
        assert!(!s.warm_started);
        assert_eq!(s.evaluations, 100, "Eq. (1): num_opt * max_iter");
        assert_eq!(
            s.cache_hits + s.cache_misses,
            s.evaluations,
            "every evaluation is either a hit or a miss"
        );
        assert!(s.best_cost.is_finite());
        assert!(
            (s.best_point[0] - 48.0).abs() <= 16.0,
            "best {:?} too far from optimum 48",
            s.best_point
        );
    }

    #[test]
    fn sessions_export_persistable_state() {
        let service = TuningService::new(2);
        let spec = SessionSpec::synthetic("exp", 48.0, 7).with_budget(4, 6);
        let report = service.run(&[spec.clone()]).unwrap();
        assert_eq!(report.states.len(), 1);
        let st = &report.states[0];
        assert_eq!(st.id, "exp");
        assert_eq!(st.fingerprint, spec.fingerprint());
        assert_eq!(st.optimizer, "csa");
        assert_eq!(st.best_point, report.sessions[0].best_point);
        assert_eq!(st.opt_state.points.len(), 4, "one point per CSA chain");
        assert_eq!(st.env.hash, EnvFingerprint::current().hash);
    }

    #[test]
    fn latest_state_wins_per_session_id() {
        let service = TuningService::new(1);
        let spec = SessionSpec::synthetic("dup", 48.0, 7).with_budget(4, 6);
        service.run(&[spec.clone()]).unwrap();
        let mut again = spec;
        again.seed = 8;
        service.run(&[again]).unwrap();
        let report = service.report();
        assert_eq!(report.sessions.len(), 2, "history keeps both runs");
        assert_eq!(report.states.len(), 1, "states dedupe by id");
        assert_eq!(report.states[0].seed, 8, "latest run's state wins");
    }

    #[test]
    fn repeated_batch_is_answered_from_cache() {
        let service = TuningService::new(2);
        let spec = SessionSpec::synthetic("warm", 32.0, 3).with_budget(4, 10);
        let first = service.run(std::slice::from_ref(&spec)).unwrap();
        let mut again = spec.clone();
        again.id = "rerun".into();
        let second = service.run(std::slice::from_ref(&again)).unwrap();
        let (a, b) = (&first.sessions[0], &second.sessions[0]);
        // Identical seed + deterministic target ⇒ identical trajectory…
        assert_eq!(a.best_point, b.best_point);
        assert_eq!(a.best_cost, b.best_cost);
        // …and the rerun was served entirely from the shared cache.
        assert_eq!(b.cache_misses, 0, "rerun must be all hits: {b:?}");
        assert_eq!(b.cache_hits, b.evaluations);
        assert_eq!(b.target_iterations, 0);
    }

    #[test]
    fn service_registry_accumulates_across_runs() {
        let service = TuningService::new(2);
        service.run(&[SessionSpec::synthetic("a", 10.0, 1)]).unwrap();
        service.run(&[SessionSpec::synthetic("b", 20.0, 2)]).unwrap();
        let report = service.report();
        let ids: Vec<&str> = report.sessions.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b"]);
        assert_eq!(report.states.len(), 2);
        assert!(report.cache.hits + report.cache.misses > 0);
    }

    #[test]
    fn grid_session_scans_the_lattice() {
        let service = TuningService::new(1);
        let mut spec = SessionSpec::synthetic("grid", 24.0, 5)
            .with_optimizer(OptimizerSpec::Grid)
            .with_budget(4, 8);
        // Grid over [1, 32] with 32 points per dim is exhaustive.
        spec.workload = WorkloadSpec::Synthetic {
            optimum: 24.0,
            dim: 1,
            lo: 1.0,
            hi: 32.0,
            kind: PointKind::Integer,
        };
        let report = service.run(&[spec]).unwrap();
        let s = &report.sessions[0];
        // The grid over [1, 32] with 32 points per dim is exhaustive, so
        // the session must land exactly on the model's integer argmin
        // (which sits slightly above `optimum` — imbalance is cheaper than
        // contention near the minimum).
        let argmin = (1..=32)
            .map(|v| v as f64)
            .min_by(|&a, &b| {
                pure_cost(&[a], 24.0)
                    .partial_cmp(&pure_cost(&[b], 24.0))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(s.best_point, vec![argmin], "exhaustive scan finds the argmin");
        assert_eq!(s.evaluations, 32);
    }

    #[test]
    fn plan_retune_skips_fresh_and_rebuilds_drifted() {
        let service = TuningService::new(1);
        let specs = vec![
            SessionSpec::synthetic("s0", 48.0, 1).with_budget(4, 10),
            SessionSpec::synthetic("s1", 24.0, 2)
                .with_optimizer(OptimizerSpec::NelderMead)
                .with_budget(4, 10),
        ];
        let report = service.run(&specs).unwrap();
        assert_eq!(report.states.len(), 2);

        // Same environment: everything is fresh, nothing to do.
        let here = EnvFingerprint::current();
        let plan = plan_retune(&report.states, &here, 50, false).unwrap();
        assert!(plan.specs.is_empty());
        assert_eq!(plan.fresh, vec!["s0", "s1"]);

        // Drifted environment: both sessions come back warm-started with
        // half the budget.
        let elsewhere = EnvFingerprint::new("threads=1024/os=plan9");
        assert!(elsewhere.drifted_from(&here));
        let plan = plan_retune(&report.states, &elsewhere, 50, false).unwrap();
        assert_eq!(plan.drifted, vec!["s0", "s1"]);
        assert_eq!(plan.specs.len(), 2);
        for (spec, st) in plan.specs.iter().zip(&report.states) {
            assert_eq!(spec.max_iter, 5, "half of the original 10");
            assert_eq!(spec.num_opt, st.num_opt);
            assert_eq!(spec.fingerprint(), st.fingerprint);
            assert!(spec.warm.is_some());
            spec.validate().unwrap();
        }

        // Force re-tunes even without drift.
        let plan = plan_retune(&report.states, &here, 30, true).unwrap();
        assert_eq!(plan.drifted.len(), 2);
        assert_eq!(plan.specs[0].max_iter, 3);
    }

    #[test]
    fn retuned_sessions_run_and_mark_warm() {
        let service = TuningService::new(2);
        let specs = vec![SessionSpec::synthetic("rt", 48.0, 7).with_budget(5, 20)];
        let report = service.run(&specs).unwrap();

        let elsewhere = EnvFingerprint::new("threads=1024/os=plan9");
        let plan = plan_retune(&report.states, &elsewhere, 40, false).unwrap();
        let rerun = TuningService::new(2);
        let second = rerun.run(&plan.specs).unwrap();
        let s = &second.sessions[0];
        assert!(s.warm_started, "retuned session must be warm-started");
        assert_eq!(s.evaluations, 5 * 8, "40% of max_iter 20 = 8 iterations");
        assert!(
            s.best_cost <= report.sessions[0].best_cost,
            "unchanged landscape: warm rerun cannot regress ({} vs {})",
            s.best_cost,
            report.sessions[0].best_cost
        );
        // The re-tuned session's persisted state must carry the *original*
        // budget, so a second retune reduces from 20 again — percentages
        // must not compound across successive drifts.
        assert_eq!(second.states[0].max_iter, 20, "budget must not compound");
        let plan2 = plan_retune(&second.states, &elsewhere, 40, true).unwrap();
        assert_eq!(plan2.specs[0].max_iter, 8, "still 40% of the original 20");
    }

    #[test]
    fn handle_speaks_the_request_response_api() {
        let service = TuningService::new(1);

        // Ping on an empty service.
        match service.handle(Request::Ping) {
            Response::Pong {
                version,
                sessions,
                draining,
            } => {
                assert_eq!(version, proto::PROTO_VERSION);
                assert_eq!(sessions, 0);
                assert!(!draining);
            }
            other => panic!("unexpected {other:?}"),
        }

        // First tune runs; the identical second one is answered from the
        // converged entry without re-running.
        let spec = SessionSpec::synthetic("h", 48.0, 7).with_budget(4, 6);
        let first = match service.handle(Request::Tune {
            spec: spec.clone(),
            fresh: false,
        }) {
            Response::Session { report, cached } => {
                assert!(!cached);
                report
            }
            other => panic!("unexpected {other:?}"),
        };
        match service.handle(Request::Tune {
            spec: spec.clone(),
            fresh: false,
        }) {
            Response::Session { report, cached } => {
                assert!(cached, "identical request must hit the fast path");
                assert_eq!(report, first);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(service.fast_hits(), 1);

        // `fresh` forces a re-run past the converged entry.
        match service.handle(Request::Tune { spec, fresh: true }) {
            Response::Session { cached, .. } => assert!(!cached),
            other => panic!("unexpected {other:?}"),
        }

        // Invalid specs come back as typed errors, not panics.
        let bad = SessionSpec::synthetic("bad id", 48.0, 7);
        assert!(matches!(
            service.handle(Request::Tune {
                spec: bad,
                fresh: false
            }),
            Response::Error(_)
        ));

        // Report sees the history; retune in an unchanged environment is
        // all-fresh.
        match service.handle(Request::Report) {
            Response::Report(r) => {
                assert_eq!(r.sessions.len(), 2, "cached answers never re-log");
                assert_eq!(r.states.len(), 1);
            }
            other => panic!("unexpected {other:?}"),
        }
        match service.handle(Request::Retune {
            budget: 50,
            force: false,
        }) {
            Response::Retuned { drifted, fresh } => {
                assert!(drifted.is_empty());
                assert_eq!(fresh, vec!["h"]);
            }
            other => panic!("unexpected {other:?}"),
        }

        // Shutdown drains: new sessions are refused, reads still work.
        assert!(matches!(
            service.handle(Request::Shutdown),
            Response::Draining
        ));
        assert!(service.is_draining());
        assert!(matches!(
            service.handle(Request::Tune {
                spec: SessionSpec::synthetic("late", 48.0, 7),
                fresh: false
            }),
            Response::Draining
        ));
        assert!(matches!(service.handle(Request::Report), Response::Report(_)));
    }

    #[test]
    fn compaction_and_snapshot_keep_the_latest_run_per_id() {
        let service = TuningService::new(1);
        let spec = SessionSpec::synthetic("c", 48.0, 7).with_budget(4, 6);
        service.run(std::slice::from_ref(&spec)).unwrap();
        let mut again = spec;
        again.seed = 9;
        service.run(&[again, SessionSpec::synthetic("d", 24.0, 1)]).unwrap();

        assert_eq!(service.report().sessions.len(), 3);
        let snap = service.registry_snapshot();
        assert_eq!(snap.sessions.len(), 2, "snapshot is compacted");
        let ids: Vec<&str> = snap.sessions.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["c", "d"], "sorted by id");

        assert_eq!(service.compact_history(), 1, "one duplicate dropped");
        assert_eq!(service.compact_history(), 0, "idempotent");
        let after = service.report();
        assert_eq!(after.sessions.len(), 2);
        let ids: Vec<&str> = after.sessions.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["c", "d"], "run order preserved");

        // A fresh service seeded from the snapshot answers from state.
        let heir = TuningService::new(1);
        heir.seed_from(&snap);
        let mut warm = SessionSpec::synthetic("c", 48.0, 9).with_budget(4, 6);
        warm.seed = 9;
        match heir.handle(Request::Tune {
            spec: warm,
            fresh: false,
        }) {
            Response::Session { cached, .. } => {
                assert!(cached, "seeded sessions answer without re-running")
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
