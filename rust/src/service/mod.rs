//! The concurrent multi-session tuning service.
//!
//! The paper tunes one parameter set for one application at a time. A
//! production deployment faces *many* tuning scenarios at once — several
//! workloads × optimizers × domains, re-tuned as conditions change (cf. HPX
//! Smart Executors and Karcher & Pankratius's concurrent-autotuning work).
//! This module is the scaling substrate for that: it runs a batch of
//! [`SessionSpec`]s concurrently and stacks three multipliers on top of the
//! staged optimizer core:
//!
//! 1. **Inter-session concurrency** — sessions execute on a persistent
//!    [`crate::sched::ThreadPool`] with bounded parallelism (the service's
//!    `concurrency`), claimed FCFS via `Schedule::Dynamic(1)`.
//! 2. **Intra-session batching** — each optimizer iteration's candidate
//!    population is pulled with [`NumericalOptimizer::run_batch`] and
//!    evaluated as a batch instead of the staged one-at-a-time loop (CSA
//!    overrides the hook to expose whole populations; every other optimizer
//!    degrades to batches of one). Pure targets evaluate their batch in
//!    parallel when the session is not itself inside a pool region.
//! 3. **Cross-session caching** — evaluations are memoised in a shared
//!    [`PointCache`] keyed by (workload fingerprint, quantised point), so a
//!    candidate repeated anywhere — within a session or across sessions —
//!    is free.
//!
//! Determinism: a session's optimizer trajectory depends only on its seed
//! and the evaluated costs. For deterministic targets (the `synthetic`
//! landscape) cached costs equal fresh ones exactly, so a session's result
//! is bit-identical whether it runs alone, serially, or among concurrent
//! sessions — `tests/service.rs` pins this.
//!
//! Results land in a [`registry`] the CLI (`patsma service run|report`) and
//! the coordinator (experiment E12) consume.

pub mod cache;
pub mod registry;

pub use cache::{fingerprint_str, CacheStats, PointCache};
pub use registry::{ServiceReport, SessionReport};

use crate::optimizer::{
    Csa, CsaConfig, GridSearch, NelderMead, NelderMeadConfig, NumericalOptimizer, ParticleSwarm,
    PsoConfig, RandomSearch, SaConfig, SimulatedAnnealing,
};
use crate::sched::{Schedule, ThreadPool};
use crate::tuner::{quantize_integer, rescale_internal};
use crate::workloads::{self, synthetic, Workload};
use anyhow::{bail, Result};
use std::sync::Mutex;
use std::time::Instant;

/// Which optimizer a session drives (the string forms match the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerSpec {
    /// Coupled Simulated Annealing (the paper's primary method).
    Csa,
    /// Nelder–Mead simplex.
    NelderMead,
    /// Single uncoupled SA chain.
    Sa,
    /// Uniform random search.
    Random,
    /// Particle swarm.
    Pso,
    /// Exhaustive lattice.
    Grid,
}

impl OptimizerSpec {
    /// Parse the CLI form (`csa|nm|sa|random|pso|grid`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "csa" => Self::Csa,
            "nm" => Self::NelderMead,
            "sa" => Self::Sa,
            "random" => Self::Random,
            "pso" => Self::Pso,
            "grid" => Self::Grid,
            other => bail!("unknown optimizer {other:?} (csa|nm|sa|random|pso|grid)"),
        })
    }

    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Csa => "csa",
            Self::NelderMead => "nm",
            Self::Sa => "sa",
            Self::Random => "random",
            Self::Pso => "pso",
            Self::Grid => "grid",
        }
    }

    /// Instantiate with the session's budget, mirroring the CLI's optimizer
    /// factory: population methods read (`num_opt`, `max_iter`) directly,
    /// sequential methods get the equalised `num_opt * max_iter` evaluation
    /// budget.
    pub fn build(
        &self,
        dim: usize,
        num_opt: usize,
        max_iter: usize,
        seed: u64,
    ) -> Box<dyn NumericalOptimizer> {
        match self {
            Self::Csa => Box::new(Csa::new(
                CsaConfig::new(dim, num_opt, max_iter).with_seed(seed),
            )),
            Self::NelderMead => Box::new(NelderMead::new(
                NelderMeadConfig::new(dim, 1e-9, num_opt * max_iter).with_seed(seed),
            )),
            Self::Sa => Box::new(SimulatedAnnealing::new(
                SaConfig::new(dim, num_opt * max_iter).with_seed(seed),
            )),
            Self::Random => Box::new(RandomSearch::new(dim, num_opt * max_iter, seed)),
            Self::Pso => Box::new(ParticleSwarm::new(
                PsoConfig::new(dim, num_opt, max_iter).with_seed(seed),
            )),
            Self::Grid => Box::new(GridSearch::new(dim, (num_opt * max_iter).max(2))),
        }
    }
}

/// What a session evaluates.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// The deterministic closed-form chunk-cost landscape
    /// ([`synthetic::chunk_cost_model`], summed over dimensions, minimum at
    /// `optimum` per coordinate). Pure: batch members evaluate in parallel
    /// and cached costs are exact.
    Synthetic {
        /// Per-coordinate location of the cost minimum (user domain).
        optimum: f64,
        /// Number of tuned parameters.
        dim: usize,
        /// Scalar lower bound, broadcast to all dimensions.
        lo: f64,
        /// Scalar upper bound, broadcast to all dimensions.
        hi: f64,
    },
    /// A real shared-memory workload from [`workloads::by_name`]; the cost
    /// is the measured wall-clock of one target iteration (after `ignore`
    /// stabilisation iterations), so cached costs are the *measured* value
    /// of the point's first run.
    Named(String),
}

impl WorkloadSpec {
    /// Whitespace-free descriptor — the registry label and the cache
    /// fingerprint input. Everything that changes the cost landscape must
    /// appear here, or distinct landscapes would share cache entries.
    pub fn descriptor(&self) -> String {
        match self {
            Self::Synthetic {
                optimum,
                dim,
                lo,
                hi,
            } => format!("synthetic/opt={optimum}/dim={dim}/lo={lo}/hi={hi}"),
            Self::Named(name) => format!("named/{name}"),
        }
    }

    /// Stable cache fingerprint.
    pub fn fingerprint(&self) -> u64 {
        fingerprint_str(&self.descriptor())
    }
}

/// One tuning scenario: workload × optimizer × domain × budget.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSpec {
    /// Report label (no whitespace).
    pub id: String,
    /// What to evaluate.
    pub workload: WorkloadSpec,
    /// Which optimizer drives the session.
    pub optimizer: OptimizerSpec,
    /// Stabilisation iterations per measured candidate (paper §2.3;
    /// a no-op for pure targets, which have nothing to stabilise).
    pub ignore: u32,
    /// Optimizer population size (`num_opt`).
    pub num_opt: usize,
    /// Optimizer iteration budget (`max_iter`).
    pub max_iter: usize,
    /// RNG seed (sessions are exactly reproducible given their seed).
    pub seed: u64,
}

impl SessionSpec {
    /// A synthetic-landscape session with the default `[1, 128]` domain.
    pub fn synthetic(id: impl Into<String>, optimum: f64, seed: u64) -> Self {
        Self {
            id: id.into(),
            workload: WorkloadSpec::Synthetic {
                optimum,
                dim: 1,
                lo: 1.0,
                hi: 128.0,
            },
            optimizer: OptimizerSpec::Csa,
            ignore: 0,
            num_opt: 4,
            max_iter: 8,
            seed,
        }
    }

    /// Builder-style optimizer override.
    pub fn with_optimizer(mut self, opt: OptimizerSpec) -> Self {
        self.optimizer = opt;
        self
    }

    /// Builder-style budget override.
    pub fn with_budget(mut self, num_opt: usize, max_iter: usize) -> Self {
        self.num_opt = num_opt;
        self.max_iter = max_iter;
        self
    }

    /// Cache fingerprint for this session's evaluations. For measured
    /// (named) workloads the `ignore` protocol changes what a cost *means*
    /// (how many stabilisation iterations precede the measurement), so it
    /// is part of the key; for pure targets `ignore` is a no-op and two
    /// sessions may share entries regardless of it.
    pub fn fingerprint(&self) -> u64 {
        match &self.workload {
            WorkloadSpec::Synthetic { .. } => self.workload.fingerprint(),
            WorkloadSpec::Named(_) => fingerprint_str(&format!(
                "{}/ignore={}",
                self.workload.descriptor(),
                self.ignore
            )),
        }
    }

    /// Check the spec before any session work starts.
    pub fn validate(&self) -> Result<()> {
        if self.id.is_empty() || self.id.chars().any(char::is_whitespace) {
            bail!("session id {:?} must be non-empty and whitespace-free", self.id);
        }
        if self.num_opt == 0 {
            bail!("session {}: num_opt must be >= 1", self.id);
        }
        match &self.workload {
            WorkloadSpec::Synthetic { dim, lo, hi, .. } => {
                if *dim == 0 {
                    bail!("session {}: dim must be >= 1", self.id);
                }
                if !(lo.is_finite() && hi.is_finite() && lo <= hi) {
                    bail!("session {}: bad domain [{lo}, {hi}]", self.id);
                }
            }
            WorkloadSpec::Named(name) => {
                if !workloads::NAMES.contains(&name.as_str()) {
                    bail!(
                        "session {}: unknown workload {name:?}; known: {:?}",
                        self.id,
                        workloads::NAMES
                    );
                }
            }
        }
        Ok(())
    }
}

/// Instantiated evaluation target.
enum Target {
    /// Deterministic closed-form landscape.
    Pure { optimum: f64 },
    /// Stateful workload measured by wall-clock.
    Measured(Box<dyn Workload>),
}

/// The concurrent tuning runtime (see module docs).
pub struct TuningService {
    pool: ThreadPool,
    cache: PointCache,
    history: Mutex<Vec<SessionReport>>,
}

impl TuningService {
    /// A service running at most `concurrency` sessions at once (0 is
    /// promoted to 1, like [`ThreadPool::new`]).
    pub fn new(concurrency: usize) -> Self {
        Self {
            pool: ThreadPool::new(concurrency),
            cache: PointCache::new(),
            history: Mutex::new(Vec::new()),
        }
    }

    /// Session-level parallelism bound.
    pub fn concurrency(&self) -> usize {
        self.pool.threads()
    }

    /// Shared-cache counter snapshot.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Run a batch of sessions concurrently (bounded by
    /// [`concurrency`](Self::concurrency)) and return their reports in spec
    /// order. Results also accumulate into the service's registry for
    /// [`report`](Self::report).
    pub fn run(&self, specs: &[SessionSpec]) -> Result<ServiceReport> {
        for spec in specs {
            spec.validate()?;
        }
        let sessions: Vec<SessionReport> = if specs.len() <= 1 {
            // A lone session keeps the caller thread out of a pool region,
            // so its pure batch evaluations can parallelise on the pool.
            specs
                .iter()
                .map(|s| run_session(s, &self.cache, &self.pool))
                .collect()
        } else {
            let slots: Vec<Mutex<Option<SessionReport>>> =
                specs.iter().map(|_| Mutex::new(None)).collect();
            self.pool.parallel_for(0, specs.len(), Schedule::Dynamic(1), |i| {
                let report = run_session(&specs[i], &self.cache, &self.pool);
                *slots[i].lock().unwrap() = Some(report);
            });
            slots
                .into_iter()
                .map(|slot| slot.into_inner().unwrap().expect("session completed"))
                .collect()
        };
        self.history.lock().unwrap().extend(sessions.iter().cloned());
        Ok(ServiceReport {
            sessions,
            cache: self.cache.stats(),
        })
    }

    /// Everything this service has run so far, with current cache counters
    /// — the registry the coordinator and CLI consume.
    pub fn report(&self) -> ServiceReport {
        ServiceReport {
            sessions: self.history.lock().unwrap().clone(),
            cache: self.cache.stats(),
        }
    }
}

/// Quantise one internal-domain candidate onto the session's integer
/// lattice — the exact value the application is handed *and* the cache key.
fn quantize_candidate(internal: &[f64], lo: &[f64], hi: &[f64]) -> Vec<i64> {
    internal
        .iter()
        .enumerate()
        .map(|(d, &x)| quantize_integer(rescale_internal(x, lo[d], hi[d]), lo[d], hi[d]) as i64)
        .collect()
}

/// Drive one session to completion: pull candidate batches from the
/// optimizer, evaluate them (cache-aware; in parallel for pure targets when
/// not already inside a pool region), feed the costs back.
fn run_session(spec: &SessionSpec, cache: &PointCache, pool: &ThreadPool) -> SessionReport {
    let t0 = Instant::now();
    let (mut target, dim, lo, hi) = match &spec.workload {
        WorkloadSpec::Synthetic {
            optimum,
            dim,
            lo,
            hi,
        } => (
            Target::Pure { optimum: *optimum },
            *dim,
            vec![*lo; *dim],
            vec![*hi; *dim],
        ),
        WorkloadSpec::Named(name) => {
            let w = workloads::by_name(name).expect("validated workload name");
            let (lo, hi) = w.bounds();
            let dim = w.dim();
            (Target::Measured(w), dim, lo, hi)
        }
    };
    let fingerprint = spec.fingerprint();
    let mut opt = spec
        .optimizer
        .build(dim, spec.num_opt, spec.max_iter, spec.seed);

    let mut best: Option<(Vec<i64>, f64)> = None;
    let mut cache_hits = 0u64;
    let mut cache_misses = 0u64;
    let mut target_iterations = 0u64;
    let mut costs: Vec<f64> = Vec::new();

    loop {
        let batch = opt.run_batch(&costs);
        if batch.is_empty() {
            break;
        }
        let points: Vec<Vec<i64>> = batch
            .iter()
            .map(|cand| quantize_candidate(cand, &lo, &hi))
            .collect();
        let mut hit_flags = vec![false; points.len()];
        costs = match &mut target {
            Target::Pure { optimum } => {
                let optimum = *optimum;
                let slots: Vec<Mutex<(f64, bool)>> =
                    points.iter().map(|_| Mutex::new((0.0, false))).collect();
                pool.parallel_for(0, points.len(), Schedule::Dynamic(1), |i| {
                    let (cost, hit) = cache.get_or_compute(fingerprint, &points[i], || {
                        pure_cost(&points[i], optimum)
                    });
                    *slots[i].lock().unwrap() = (cost, hit);
                });
                slots
                    .into_iter()
                    .enumerate()
                    .map(|(i, slot)| {
                        let (cost, hit) = slot.into_inner().unwrap();
                        hit_flags[i] = hit;
                        cost
                    })
                    .collect()
            }
            Target::Measured(w) => points
                .iter()
                .enumerate()
                .map(|(i, point)| {
                    let (cost, hit) = cache.get_or_compute(fingerprint, point, || {
                        let params: Vec<i32> = point.iter().map(|&v| v as i32).collect();
                        // The ignore protocol (§2.3): run `ignore`
                        // stabilisation iterations, measure the last one.
                        let mut measured = 0.0;
                        for _ in 0..=spec.ignore {
                            let t = Instant::now();
                            let _ = w.run_iteration(&params);
                            measured = t.elapsed().as_secs_f64();
                        }
                        measured
                    });
                    hit_flags[i] = hit;
                    cost
                })
                .collect(),
        };
        // Sequential, index-ordered bookkeeping keeps the session report
        // deterministic regardless of evaluation interleaving.
        for (i, point) in points.iter().enumerate() {
            if hit_flags[i] {
                cache_hits += 1;
            } else {
                cache_misses += 1;
                target_iterations += match &target {
                    // Pure targets evaluate once; there is nothing to
                    // stabilise, so `ignore` adds no iterations.
                    Target::Pure { .. } => 1,
                    Target::Measured(_) => (spec.ignore as u64) + 1,
                };
            }
            let cost = costs[i];
            if best.as_ref().map(|(_, c)| cost < *c).unwrap_or(true) {
                best = Some((point.clone(), cost));
            }
        }
    }

    let (best_point, best_cost) = best.unwrap_or((vec![0; dim], f64::INFINITY));
    SessionReport {
        id: spec.id.clone(),
        workload: spec.workload.descriptor(),
        optimizer: opt.name().to_string(),
        evaluations: opt.evaluations(),
        target_iterations,
        cache_hits,
        cache_misses,
        best_point,
        best_cost,
        wall_secs: t0.elapsed().as_secs_f64(),
    }
}

/// The deterministic session landscape: the chunk-cost model summed over
/// dimensions (minimum at `optimum` per coordinate).
fn pure_cost(point: &[i64], optimum: f64) -> f64 {
    point
        .iter()
        .map(|&p| synthetic::chunk_cost_model(p as f64, optimum))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimizer_spec_parse_roundtrip() {
        for s in ["csa", "nm", "sa", "random", "pso", "grid"] {
            let spec = OptimizerSpec::parse(s).unwrap();
            assert_eq!(spec.name(), s);
        }
        assert!(OptimizerSpec::parse("bogus").is_err());
    }

    #[test]
    fn workload_descriptors_are_distinct_and_clean() {
        let a = WorkloadSpec::Synthetic {
            optimum: 48.0,
            dim: 1,
            lo: 1.0,
            hi: 128.0,
        };
        let b = WorkloadSpec::Synthetic {
            optimum: 24.0,
            dim: 1,
            lo: 1.0,
            hi: 128.0,
        };
        let c = WorkloadSpec::Named("spmv".into());
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        for w in [a, b, c] {
            assert!(!w.descriptor().contains(char::is_whitespace));
        }
    }

    #[test]
    fn named_session_fingerprint_depends_on_ignore() {
        // The ignore protocol changes what a measured cost means, so two
        // sessions over one named workload with different `ignore` must not
        // share cache entries; for pure targets ignore is a no-op and they
        // must share.
        let mut a = SessionSpec::synthetic("a", 48.0, 1);
        a.workload = WorkloadSpec::Named("spmv".into());
        let mut b = a.clone();
        b.ignore = 3;
        assert_ne!(a.fingerprint(), b.fingerprint());

        let p = SessionSpec::synthetic("p", 48.0, 1);
        let mut q = p.clone();
        q.ignore = 3;
        assert_eq!(p.fingerprint(), q.fingerprint());
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let mut s = SessionSpec::synthetic("ok", 48.0, 1);
        s.validate().unwrap();
        s.id = "has space".into();
        assert!(s.validate().is_err());
        s.id = "ok".into();
        s.num_opt = 0;
        assert!(s.validate().is_err());
        s.num_opt = 4;
        s.workload = WorkloadSpec::Named("nope".into());
        assert!(s.validate().is_err());
        s.workload = WorkloadSpec::Synthetic {
            optimum: 1.0,
            dim: 0,
            lo: 1.0,
            hi: 2.0,
        };
        assert!(s.validate().is_err());
        s.workload = WorkloadSpec::Synthetic {
            optimum: 1.0,
            dim: 1,
            lo: 5.0,
            hi: 2.0,
        };
        assert!(s.validate().is_err());
    }

    #[test]
    fn single_session_finds_the_synthetic_optimum_region() {
        let service = TuningService::new(2);
        let spec = SessionSpec::synthetic("solo", 48.0, 7).with_budget(5, 20);
        let report = service.run(std::slice::from_ref(&spec)).unwrap();
        let s = &report.sessions[0];
        assert_eq!(s.id, "solo");
        assert_eq!(s.optimizer, "csa");
        assert_eq!(s.evaluations, 100, "Eq. (1): num_opt * max_iter");
        assert_eq!(
            s.cache_hits + s.cache_misses,
            s.evaluations,
            "every evaluation is either a hit or a miss"
        );
        assert!(s.best_cost.is_finite());
        assert!(
            (s.best_point[0] - 48).abs() <= 16,
            "best {:?} too far from optimum 48",
            s.best_point
        );
    }

    #[test]
    fn repeated_batch_is_answered_from_cache() {
        let service = TuningService::new(2);
        let spec = SessionSpec::synthetic("warm", 32.0, 3).with_budget(4, 10);
        let first = service.run(std::slice::from_ref(&spec)).unwrap();
        let mut again = spec.clone();
        again.id = "rerun".into();
        let second = service.run(std::slice::from_ref(&again)).unwrap();
        let (a, b) = (&first.sessions[0], &second.sessions[0]);
        // Identical seed + deterministic target ⇒ identical trajectory…
        assert_eq!(a.best_point, b.best_point);
        assert_eq!(a.best_cost, b.best_cost);
        // …and the rerun was served entirely from the shared cache.
        assert_eq!(b.cache_misses, 0, "rerun must be all hits: {b:?}");
        assert_eq!(b.cache_hits, b.evaluations);
        assert_eq!(b.target_iterations, 0);
    }

    #[test]
    fn service_registry_accumulates_across_runs() {
        let service = TuningService::new(2);
        service.run(&[SessionSpec::synthetic("a", 10.0, 1)]).unwrap();
        service.run(&[SessionSpec::synthetic("b", 20.0, 2)]).unwrap();
        let report = service.report();
        let ids: Vec<&str> = report.sessions.iter().map(|s| s.id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b"]);
        assert!(report.cache.hits + report.cache.misses > 0);
    }

    #[test]
    fn grid_session_scans_the_lattice() {
        let service = TuningService::new(1);
        let mut spec = SessionSpec::synthetic("grid", 24.0, 5)
            .with_optimizer(OptimizerSpec::Grid)
            .with_budget(4, 8);
        // Grid over [1, 32] with 32 points per dim is exhaustive.
        spec.workload = WorkloadSpec::Synthetic {
            optimum: 24.0,
            dim: 1,
            lo: 1.0,
            hi: 32.0,
        };
        let report = service.run(&[spec]).unwrap();
        let s = &report.sessions[0];
        // The grid over [1, 32] with 32 points per dim is exhaustive, so
        // the session must land exactly on the model's integer argmin
        // (which sits slightly above `optimum` — imbalance is cheaper than
        // contention near the minimum).
        let argmin = (1..=32i64)
            .min_by(|&a, &b| {
                pure_cost(&[a], 24.0)
                    .partial_cmp(&pure_cost(&[b], 24.0))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(s.best_point, vec![argmin], "exhaustive scan finds the argmin");
        assert_eq!(s.evaluations, 32);
    }
}
