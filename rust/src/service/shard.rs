//! Sharded session map — the daemon's concurrent registry of finished
//! sessions.
//!
//! A long-lived daemon answers most requests from state it already holds: a
//! converged session is a read, not a tuning run. One big mutex around a
//! `Vec<SessionState>` (the pre-0.7 shape) serialises every reader behind
//! every writer; [`ShardedSessions`] splits the map into N shards selected
//! by a hash of the session's **workload fingerprint mixed with the
//! environment fingerprint**, so sessions over different landscapes almost
//! never contend, and reads take only a shard-local `RwLock` read guard —
//! the lock-free-in-practice fast path for converged sessions (many
//! concurrent readers, zero writers).
//!
//! Entries dedupe by session id across *all* shards (latest wins), matching
//! the registry's "latest state wins per id" rule.

use super::cache::{fingerprint_str, fnv1a};
use super::registry::SessionReport;
use super::state::SessionState;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Default shard count (rounded up to a power of two by the constructor).
pub const DEFAULT_SHARDS: usize = 16;

/// One finished session as the daemon retains it.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionEntry {
    /// The session's report (what a `tune` response carries).
    pub report: SessionReport,
    /// Persisted optimizer state, when the optimizer supports export.
    pub state: Option<SessionState>,
    /// Landscape identity the entry answers for.
    pub fingerprint: u64,
    /// Converged entries answer matching `tune` requests without
    /// re-running (the read fast path).
    pub converged: bool,
}

/// The N-way sharded session map (see module docs).
pub struct ShardedSessions {
    shards: Vec<RwLock<HashMap<String, Arc<SessionEntry>>>>,
    /// Environment hash mixed into shard selection, so one workload's
    /// sessions land on different shards under different environments.
    env_hash: u64,
    /// Requests answered from a converged entry without any tuning run.
    fast_hits: AtomicU64,
}

impl ShardedSessions {
    /// A map with `shards` shards (rounded up to a power of two, min 1)
    /// under the `env_hash` environment.
    pub fn new(shards: usize, env_hash: u64) -> Self {
        let n = shards.max(1).next_power_of_two();
        Self {
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            env_hash,
            fast_hits: AtomicU64::new(0),
        }
    }

    /// Shard count (power of two).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Which shard a landscape lives on.
    fn shard_index(&self, fingerprint: u64) -> usize {
        let mixed = fnv1a((fingerprint ^ self.env_hash).to_le_bytes());
        (mixed as usize) & (self.shards.len() - 1)
    }

    /// Read a session entry (read-lock only — the fast path). Counts a
    /// fast hit when the entry is converged over the same landscape.
    pub fn get(&self, fingerprint: u64, id: &str) -> Option<Arc<SessionEntry>> {
        let shard = self.shards[self.shard_index(fingerprint)].read().unwrap();
        let entry = shard.get(id)?.clone();
        if entry.converged && entry.fingerprint == fingerprint {
            self.fast_hits.fetch_add(1, Ordering::Relaxed);
        }
        Some(entry)
    }

    /// Insert (or replace) a session entry; the id is unique across all
    /// shards, so a session re-run over a *different* landscape evicts the
    /// stale entry from whatever shard it used to live on.
    pub fn insert(&self, entry: SessionEntry) {
        let target = self.shard_index(entry.fingerprint);
        let id = entry.report.id.clone();
        for (i, shard) in self.shards.iter().enumerate() {
            if i != target {
                shard.write().unwrap().remove(&id);
            }
        }
        self.shards[target]
            .write()
            .unwrap()
            .insert(id, Arc::new(entry));
    }

    /// Number of sessions held across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    /// True when no sessions are held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many requests were answered from a converged entry.
    pub fn fast_hits(&self) -> u64 {
        self.fast_hits.load(Ordering::Relaxed)
    }

    /// A consistent-enough snapshot for persistence: the latest report and
    /// state per session id, sorted by id (the compacted registry body).
    /// Shards are visited one read guard at a time — writers between
    /// shards are fine; the registry's per-id rule still holds.
    pub fn snapshot(&self) -> (Vec<SessionReport>, Vec<SessionState>) {
        let mut entries: Vec<Arc<SessionEntry>> = Vec::new();
        for shard in &self.shards {
            entries.extend(shard.read().unwrap().values().cloned());
        }
        entries.sort_by(|a, b| a.report.id.cmp(&b.report.id));
        let reports = entries.iter().map(|e| e.report.clone()).collect();
        let states = entries.iter().filter_map(|e| e.state.clone()).collect();
        (reports, states)
    }

    /// Seed the map from a loaded registry: one entry per session id
    /// (latest report wins), joined with its persisted state when one
    /// exists. Loaded entries count as converged — they answer matching
    /// requests from state, exactly like sessions this process ran.
    pub fn load(&self, sessions: &[SessionReport], states: &[SessionState]) {
        for report in sessions {
            let state = states.iter().find(|s| s.id == report.id).cloned();
            let fingerprint = state
                .as_ref()
                .map(|s| s.fingerprint)
                .unwrap_or_else(|| fingerprint_str(&report.workload));
            self.insert(SessionEntry {
                report: report.clone(),
                state,
                fingerprint,
                converged: true,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: &str, fingerprint: u64, converged: bool) -> SessionEntry {
        SessionEntry {
            report: SessionReport {
                id: id.into(),
                workload: format!("w{fingerprint}"),
                optimizer: "csa".into(),
                evaluations: 8,
                target_iterations: 8,
                cache_hits: 0,
                cache_misses: 8,
                best_point: vec![1.0],
                best_label: None,
                best_cost: 0.5,
                wall_secs: 0.001,
                warm_started: false,
                extra: Vec::new(),
            },
            state: None,
            fingerprint,
            converged,
        }
    }

    #[test]
    fn shard_count_rounds_up_to_a_power_of_two() {
        assert_eq!(ShardedSessions::new(0, 1).shard_count(), 1);
        assert_eq!(ShardedSessions::new(5, 1).shard_count(), 8);
        assert_eq!(ShardedSessions::new(16, 1).shard_count(), 16);
    }

    #[test]
    fn insert_get_and_fast_hit_accounting() {
        let map = ShardedSessions::new(16, 0xABCD);
        map.insert(entry("a", 100, true));
        map.insert(entry("b", 200, false));
        assert_eq!(map.len(), 2);

        // Converged + matching landscape: a fast hit.
        assert!(map.get(100, "a").is_some());
        assert_eq!(map.fast_hits(), 1);
        // Unconverged entries are readable but never fast hits.
        assert!(map.get(200, "b").is_some());
        assert_eq!(map.fast_hits(), 1);
        // Unknown id: nothing.
        assert!(map.get(100, "zzz").is_none());
    }

    #[test]
    fn reinsert_under_a_new_landscape_evicts_the_stale_entry() {
        // With many shards, fingerprints 1 and 2 almost surely map to
        // different shards for some env hash; assert the id stays unique
        // regardless of where the entries land.
        for env in 0..8u64 {
            let map = ShardedSessions::new(16, env);
            map.insert(entry("same-id", 1, true));
            map.insert(entry("same-id", 2, true));
            assert_eq!(map.len(), 1, "env {env}: id must stay unique");
            let got = map.get(2, "same-id").expect("latest entry readable");
            assert_eq!(got.fingerprint, 2);
        }
    }

    #[test]
    fn snapshot_is_sorted_and_joins_states() {
        let map = ShardedSessions::new(4, 7);
        let mut with_state = entry("b", 2, true);
        with_state.state = Some(SessionState {
            id: "b".into(),
            workload: "w2".into(),
            fingerprint: 2,
            env: crate::service::EnvFingerprint::with_threads(4),
            optimizer: "csa".into(),
            num_opt: 4,
            max_iter: 8,
            seed: 1,
            ignore: 0,
            best_point: vec![1.0],
            best_cost: 0.5,
            opt_state: crate::optimizer::OptimizerState {
                optimizer: "csa".into(),
                best_internal: vec![0.1],
                best_cost: 0.5,
                temperatures: None,
                points: vec![vec![0.1]],
            },
            extra: Vec::new(),
        });
        map.insert(entry("c", 3, true));
        map.insert(with_state);
        map.insert(entry("a", 1, false));
        let (reports, states) = map.snapshot();
        let ids: Vec<&str> = reports.iter().map(|r| r.id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b", "c"], "sorted by id");
        assert_eq!(states.len(), 1);
        assert_eq!(states[0].id, "b");

        // load() round-trips the snapshot into an equivalent map.
        let reloaded = ShardedSessions::new(4, 7);
        reloaded.load(&reports, &states);
        assert_eq!(reloaded.len(), 3);
        let b = reloaded.get(2, "b").unwrap();
        assert!(b.converged, "loaded entries answer from state");
        assert_eq!(b.state.as_ref().unwrap().fingerprint, 2);
        // Reports without a persisted state fall back to the workload
        // descriptor fingerprint.
        let a = reloaded.get(fingerprint_str("w1"), "a").unwrap();
        assert_eq!(a.fingerprint, fingerprint_str("w1"));
    }

    #[test]
    fn snapshot_sees_every_converged_session_exactly_once_under_inserts() {
        // ISSUE 8 satellite: cross-shard iteration under concurrent insert.
        // 40 pre-seeded converged sessions must appear in *every* snapshot
        // exactly once — distinct-id inserts landing on other shards
        // mid-iteration must never hide or duplicate them.
        let map = Arc::new(ShardedSessions::new(8, 0xBEEF));
        let seeded: Vec<String> = (0..40).map(|i| format!("seed-{i:02}")).collect();
        for (i, id) in seeded.iter().enumerate() {
            map.insert(entry(id, 10_000 + i as u64, true));
        }
        let writers: Vec<_> = (0..3u64)
            .map(|t| {
                let m = map.clone();
                std::thread::spawn(move || {
                    for i in 0..2000u64 {
                        m.insert(entry(&format!("new-{t}-{i}"), t * 100_000 + i, true));
                    }
                })
            })
            .collect();
        let mut snapshots = 0u32;
        while writers.iter().any(|h| !h.is_finished()) || snapshots == 0 {
            let (reports, _) = map.snapshot();
            let ids: Vec<&str> = reports.iter().map(|r| r.id.as_str()).collect();
            // snapshot() sorts by id, so duplicates would be adjacent.
            for pair in ids.windows(2) {
                assert_ne!(pair[0], pair[1], "duplicate id in snapshot");
            }
            for id in &seeded {
                assert!(
                    ids.binary_search(&id.as_str()).is_ok(),
                    "seeded session {id} missing from snapshot {snapshots}"
                );
            }
            snapshots += 1;
        }
        for h in writers {
            h.join().unwrap();
        }
        assert!(snapshots > 0);
        let (reports, _) = map.snapshot();
        assert_eq!(reports.len(), 40 + 3 * 2000);
    }

    #[test]
    fn concurrent_readers_and_writers_do_not_lose_entries() {
        let map = std::sync::Arc::new(ShardedSessions::new(8, 42));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let m = map.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let id = format!("t{t}-{i}");
                    m.insert(entry(&id, t * 1000 + i, true));
                    assert!(m.get(t * 1000 + i, &id).is_some());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(map.len(), 200);
        assert!(map.fast_hits() >= 200);
    }
}
