//! Persistent session state — what `patsma service retune` resumes from.
//!
//! A completed tuning session leaves more behind than its best point: the
//! optimizer's population and annealing temperatures encode *where the
//! search was* when it stopped. [`SessionState`] captures all of it,
//! together with two fingerprints:
//!
//! * the **workload fingerprint** ([`super::SessionSpec::fingerprint`]) —
//!   which cost landscape the state belongs to; a state never seeds a
//!   session over a different landscape;
//! * the **environment fingerprint** ([`EnvFingerprint`]) — the execution
//!   context (thread count, OS) the costs were measured under. When it
//!   drifts, old costs are stale but old *solutions* are still excellent
//!   starting material (Karcher & Pankratius's online re-tuning premise),
//!   so the retune path warm-starts from the state with a reduced budget
//!   instead of cold-starting a full run.
//!
//! States serialise into the v2 service registry as whitespace-separated
//! `key=value` records; unknown keys are ignored on load so newer writers
//! stay readable by older readers (forward compatibility).

use super::cache::fingerprint_str;
use crate::error::PatsmaError;
use crate::optimizer::OptimizerState;
use crate::sched::ThreadPool;

/// Fingerprint of the execution environment costs were measured under.
#[derive(Debug, Clone, PartialEq)]
pub struct EnvFingerprint {
    /// Human-readable, whitespace-free descriptor (e.g.
    /// `threads=8/os=linux`). Everything that should invalidate measured
    /// costs on change belongs here.
    pub descriptor: String,
    /// Stable hash of the descriptor (what drift detection compares).
    pub hash: u64,
}

impl EnvFingerprint {
    /// Fingerprint from an explicit descriptor.
    pub fn new(descriptor: impl Into<String>) -> Self {
        let descriptor = descriptor.into();
        let hash = fingerprint_str(&descriptor);
        Self { descriptor, hash }
    }

    /// The current process environment: global-pool thread count + OS.
    pub fn current() -> Self {
        Self::with_threads(ThreadPool::global().threads())
    }

    /// Environment descriptor for an explicit thread count (tests use this
    /// to fabricate drift without re-spawning pools).
    pub fn with_threads(threads: usize) -> Self {
        Self::new(format!("threads={threads}/os={}", std::env::consts::OS))
    }

    /// True when `other` was captured under a different environment.
    pub fn drifted_from(&self, other: &EnvFingerprint) -> bool {
        self.hash != other.hash
    }
}

/// Everything needed to warm-start a session in a later process.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    /// Session label the state came from.
    pub id: String,
    /// Workload descriptor (re-parseable via
    /// [`super::WorkloadSpec::parse_descriptor`]).
    pub workload: String,
    /// The session's evaluation fingerprint (landscape identity).
    pub fingerprint: u64,
    /// Environment the costs were measured under.
    pub env: EnvFingerprint,
    /// Optimizer name (`csa`, `nm`, ...; the CLI form).
    pub optimizer: String,
    /// Population size of the original session.
    pub num_opt: usize,
    /// Iteration budget of the original session.
    pub max_iter: usize,
    /// Seed of the original session.
    pub seed: u64,
    /// Stabilisation iterations of the original session.
    pub ignore: u32,
    /// Best measured point (user domain — what the application was handed).
    pub best_point: Vec<f64>,
    /// Best measured cost (stale once the environment drifts).
    pub best_cost: f64,
    /// The optimizer's internal-domain snapshot.
    pub opt_state: OptimizerState,
    /// Keys this build does not understand, preserved verbatim so a load →
    /// snapshot roundtrip through an older binary keeps a newer writer's
    /// fields (registry compatibility rules).
    pub extra: Vec<(String, String)>,
}

/// Join floats with `sep`; empty slices become the `-` sentinel so every
/// value stays non-empty (the registry format splits on whitespace).
fn join_f64(values: &[f64], sep: char) -> String {
    if values.is_empty() {
        "-".to_string()
    } else {
        values
            .iter()
            .map(|v| format!("{v}"))
            .collect::<Vec<_>>()
            .join(&sep.to_string())
    }
}

/// Inverse of [`join_f64`].
fn split_f64(text: &str, sep: char) -> Result<Vec<f64>, PatsmaError> {
    if text == "-" {
        return Ok(Vec::new());
    }
    text.split(sep)
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| PatsmaError::registry(format!("bad float {v:?}")))
        })
        .collect()
}

impl SessionState {
    /// Serialise as ordered `key=value` pairs (the v2 registry record body).
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let points = if self.opt_state.points.is_empty() {
            "-".to_string()
        } else {
            self.opt_state
                .points
                .iter()
                .map(|p| join_f64(p, ','))
                .collect::<Vec<_>>()
                .join(";")
        };
        let mut kv = vec![
            ("id".to_string(), self.id.clone()),
            ("workload".to_string(), self.workload.clone()),
            ("fingerprint".to_string(), self.fingerprint.to_string()),
            ("env".to_string(), self.env.descriptor.clone()),
            ("optimizer".to_string(), self.optimizer.clone()),
            // The trait-level name the snapshot checks on warm start (the
            // CLI form above can differ, e.g. `nm` vs `nelder-mead`).
            ("impl".to_string(), self.opt_state.optimizer.clone()),
            ("num_opt".to_string(), self.num_opt.to_string()),
            ("max_iter".to_string(), self.max_iter.to_string()),
            ("seed".to_string(), self.seed.to_string()),
            ("ignore".to_string(), self.ignore.to_string()),
            ("best".to_string(), join_f64(&self.best_point, ',')),
            ("best_cost".to_string(), format!("{}", self.best_cost)),
            (
                "sbest".to_string(),
                join_f64(&self.opt_state.best_internal, ','),
            ),
            (
                "sbest_cost".to_string(),
                format!("{}", self.opt_state.best_cost),
            ),
            ("points".to_string(), points),
        ];
        if let Some((t_gen, t_ac)) = self.opt_state.temperatures {
            kv.push(("tgen".to_string(), format!("{t_gen}")));
            kv.push(("tac".to_string(), format!("{t_ac}")));
        }
        kv.extend(self.extra.iter().cloned());
        kv
    }

    /// Keys `to_kv`/`from_kv` understand; anything else lands in `extra`.
    const KNOWN_KEYS: [&'static str; 17] = [
        "id",
        "workload",
        "fingerprint",
        "env",
        "optimizer",
        "impl",
        "num_opt",
        "max_iter",
        "seed",
        "ignore",
        "best",
        "best_cost",
        "sbest",
        "sbest_cost",
        "points",
        "tgen",
        "tac",
    ];

    /// Parse from `key=value` pairs. Unknown keys are preserved in `extra`
    /// (forward compatibility); missing required keys are a typed
    /// [`PatsmaError::Registry`].
    pub fn from_kv(pairs: &[(&str, &str)]) -> Result<SessionState, PatsmaError> {
        let get = |key: &str| -> Result<&str, PatsmaError> {
            pairs
                .iter()
                .find(|(k, _)| *k == key)
                .map(|(_, v)| *v)
                .ok_or_else(|| PatsmaError::registry(format!("state record missing {key:?}")))
        };
        let opt_get = |key: &str| pairs.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
        let parse_num = |key: &str, v: &str| -> Result<f64, PatsmaError> {
            v.parse::<f64>()
                .map_err(|_| PatsmaError::registry(format!("state record: bad {key} {v:?}")))
        };
        let optimizer = get("optimizer")?.to_string();
        let impl_name = opt_get("impl").unwrap_or(&optimizer).to_string();
        let points_text = get("points")?;
        let points = if points_text == "-" {
            Vec::new()
        } else {
            points_text
                .split(';')
                .map(|p| split_f64(p, ','))
                .collect::<Result<Vec<_>, PatsmaError>>()
                .map_err(|e| PatsmaError::registry(format!("state record: bad points: {e}")))?
        };
        let temperatures = match (opt_get("tgen"), opt_get("tac")) {
            (Some(tg), Some(ta)) => Some((parse_num("tgen", tg)?, parse_num("tac", ta)?)),
            _ => None,
        };
        let best_internal = split_f64(get("sbest")?, ',')
            .map_err(|e| PatsmaError::registry(format!("state record: bad sbest: {e}")))?;
        if best_internal.is_empty() {
            return Err(PatsmaError::registry("state record: empty sbest"));
        }
        let parse_int = |key: &str, v: &str| -> Result<u64, PatsmaError> {
            v.parse::<u64>()
                .map_err(|_| PatsmaError::registry(format!("state record: bad {key} {v:?}")))
        };
        Ok(SessionState {
            id: get("id")?.to_string(),
            workload: get("workload")?.to_string(),
            fingerprint: parse_int("fingerprint", get("fingerprint")?)?,
            env: EnvFingerprint::new(get("env")?),
            optimizer: optimizer.clone(),
            num_opt: parse_int("num_opt", get("num_opt")?)? as usize,
            max_iter: parse_int("max_iter", get("max_iter")?)? as usize,
            seed: parse_int("seed", get("seed")?)?,
            ignore: parse_int("ignore", get("ignore")?)? as u32,
            best_point: split_f64(get("best")?, ',')
                .map_err(|e| PatsmaError::registry(format!("state record: bad best: {e}")))?,
            best_cost: parse_num("best_cost", get("best_cost")?)?,
            opt_state: OptimizerState {
                optimizer: impl_name,
                best_internal,
                best_cost: parse_num("sbest_cost", get("sbest_cost")?)?,
                temperatures,
                points,
            },
            extra: pairs
                .iter()
                .filter(|(k, _)| !Self::KNOWN_KEYS.contains(k))
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_state() -> SessionState {
        SessionState {
            id: "s0".into(),
            workload: "synthetic/opt=48/dim=1/lo=1/hi=128/kind=int".into(),
            fingerprint: 0xDEAD_BEEF,
            env: EnvFingerprint::with_threads(8),
            optimizer: "csa".into(),
            num_opt: 4,
            max_iter: 8,
            seed: 42,
            ignore: 0,
            best_point: vec![47.0],
            best_cost: 1.25e-3,
            opt_state: OptimizerState {
                optimizer: "csa".into(),
                best_internal: vec![-0.28],
                best_cost: 1.25e-3,
                temperatures: Some((0.125, 1.75)),
                points: vec![vec![-0.28], vec![0.5], vec![-0.9], vec![0.1]],
            },
            extra: Vec::new(),
        }
    }

    #[test]
    fn kv_roundtrip_is_lossless() {
        let state = sample_state();
        let kv = state.to_kv();
        let borrowed: Vec<(&str, &str)> =
            kv.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        let parsed = SessionState::from_kv(&borrowed).unwrap();
        assert_eq!(parsed, state);
    }

    #[test]
    fn kv_values_are_whitespace_free() {
        for (k, v) in sample_state().to_kv() {
            assert!(!v.is_empty(), "{k} empty");
            assert!(
                !v.contains(char::is_whitespace),
                "{k}={v:?} contains whitespace"
            );
        }
    }

    #[test]
    fn unknown_keys_are_preserved() {
        let kv = sample_state().to_kv();
        let mut borrowed: Vec<(&str, &str)> =
            kv.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        borrowed.push(("from_the_future", "whatever"));
        let parsed = SessionState::from_kv(&borrowed).unwrap();
        assert_eq!(
            parsed.extra,
            vec![("from_the_future".to_string(), "whatever".to_string())]
        );
        // The preserved key is written back out, so a snapshot by this
        // build keeps what a newer writer recorded.
        assert!(parsed
            .to_kv()
            .iter()
            .any(|(k, v)| k == "from_the_future" && v == "whatever"));
        let mut expected = sample_state();
        expected.extra = parsed.extra.clone();
        assert_eq!(parsed, expected);
    }

    #[test]
    fn missing_required_key_is_an_error() {
        let kv = sample_state().to_kv();
        let borrowed: Vec<(&str, &str)> = kv
            .iter()
            .filter(|(k, _)| k != "fingerprint")
            .map(|(k, v)| (k.as_str(), v.as_str()))
            .collect();
        assert!(SessionState::from_kv(&borrowed).is_err());
    }

    #[test]
    fn temperatures_are_optional() {
        let mut state = sample_state();
        state.optimizer = "nm".into();
        state.opt_state.optimizer = "nm".into();
        state.opt_state.temperatures = None;
        let kv = state.to_kv();
        assert!(!kv.iter().any(|(k, _)| k == "tgen"));
        let borrowed: Vec<(&str, &str)> =
            kv.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        assert_eq!(SessionState::from_kv(&borrowed).unwrap(), state);
    }

    #[test]
    fn env_drift_detection() {
        let a = EnvFingerprint::with_threads(4);
        let b = EnvFingerprint::with_threads(8);
        assert!(a.drifted_from(&b));
        assert!(!a.drifted_from(&EnvFingerprint::with_threads(4)));
        assert!(!a.descriptor.contains(char::is_whitespace));
    }
}
