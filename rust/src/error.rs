//! Crate-wide typed error: [`PatsmaError`].
//!
//! PR 7 retires the stringly error surfaces (`anyhow!`/`bail!` with ad-hoc
//! prose) on the crate's *parsing* boundaries — [`crate::sched::Schedule::parse`],
//! the service registry loader, the wire protocol, and CLI argument
//! handling — in favour of one typed enum implementing [`std::error::Error`].
//!
//! Interop is free in both directions:
//!
//! * call sites inside `anyhow` functions keep using `?` — `anyhow::Error`
//!   absorbs any `E: Error + Send + Sync + 'static`;
//! * the daemon and wire protocol, which must map failures onto typed
//!   [`crate::service::proto::Response::Error`] records, now get a real enum
//!   to match on instead of substring-probing a message.
//!
//! Variants are grouped by boundary: `Parse`/`Unknown`/`Missing`/`Invalid`
//! for vocabulary-and-value errors, `Registry` for the persisted-state
//! codec, `Io` for filesystem and socket operations (keeps the path and
//! the underlying [`std::io::Error`] as `source()`), and
//! `Protocol`/`Draining` for the daemon's wire surface.

use std::fmt;
use std::path::{Path, PathBuf};

/// The crate-wide error type for PATSMA's parsing and service boundaries.
#[derive(Debug)]
pub enum PatsmaError {
    /// A value failed to parse as the expected type.
    Parse {
        /// What was being parsed ("schedule chunk", "flag --num-opt", …).
        what: String,
        /// The offending input, verbatim.
        input: String,
        /// Why it was rejected / what was expected.
        reason: String,
    },
    /// A name outside a fixed vocabulary (schedule kind, CLI command, …).
    Unknown {
        /// The vocabulary ("schedule kind", "command", "daemon action").
        kind: &'static str,
        /// The name that was not recognised.
        name: String,
        /// The accepted vocabulary, rendered for the user.
        expected: &'static str,
    },
    /// A required value was absent (CLI argument, record key).
    Missing {
        /// What is missing.
        what: String,
        /// How to supply it.
        hint: String,
    },
    /// A value parsed but violates a domain constraint.
    Invalid(String),
    /// The service registry text is malformed.
    Registry {
        /// 1-based line number in the registry file, when known.
        line: Option<usize>,
        /// What is wrong with the record.
        reason: String,
    },
    /// An I/O operation failed; keeps the path and the OS error as `source()`.
    Io {
        /// The operation, as a human-readable gerund ("reading registry").
        op: &'static str,
        /// The path involved.
        path: PathBuf,
        /// The underlying OS error.
        source: std::io::Error,
    },
    /// A wire frame or record violated the daemon protocol.
    Protocol(String),
    /// The daemon is draining and refuses new tuning work.
    Draining,
}

impl PatsmaError {
    /// Shorthand constructor for [`PatsmaError::Io`].
    pub fn io(op: &'static str, path: &Path, source: std::io::Error) -> Self {
        PatsmaError::Io {
            op,
            path: path.to_path_buf(),
            source,
        }
    }

    /// Shorthand constructor for a line-less [`PatsmaError::Registry`].
    pub fn registry(reason: impl Into<String>) -> Self {
        PatsmaError::Registry {
            line: None,
            reason: reason.into(),
        }
    }

    /// Attach (or replace) a registry line number, flattening nested
    /// registry errors so "line 5: registry: bad hits" cannot happen.
    pub fn at_line(self, lineno: usize) -> Self {
        let reason = match self {
            PatsmaError::Registry { reason, .. } => reason,
            other => other.to_string(),
        };
        PatsmaError::Registry {
            line: Some(lineno),
            reason,
        }
    }
}

impl fmt::Display for PatsmaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PatsmaError::Parse {
                what,
                input,
                reason,
            } => {
                write!(f, "{what}: cannot parse {input:?}: {reason}")
            }
            PatsmaError::Unknown {
                kind,
                name,
                expected,
            } => {
                write!(f, "unknown {kind} {name:?} (expected {expected})")
            }
            PatsmaError::Missing { what, hint } => write!(f, "missing {what} ({hint})"),
            PatsmaError::Invalid(reason) => write!(f, "{reason}"),
            PatsmaError::Registry { line, reason } => match line {
                Some(line) => write!(f, "registry line {line}: {reason}"),
                None => write!(f, "registry: {reason}"),
            },
            PatsmaError::Io { op, path, source } => {
                write!(f, "{op} {}: {source}", path.display())
            }
            PatsmaError::Protocol(reason) => write!(f, "protocol: {reason}"),
            PatsmaError::Draining => write!(f, "daemon is draining; no new sessions accepted"),
        }
    }
}

impl std::error::Error for PatsmaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            PatsmaError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_offender() {
        let e = PatsmaError::Parse {
            what: "flag --num-opt".into(),
            input: "many".into(),
            reason: "expected a number".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("--num-opt"), "{msg}");
        assert!(msg.contains("many"), "{msg}");
    }

    #[test]
    fn unknown_lists_the_vocabulary() {
        let e = PatsmaError::Unknown {
            kind: "schedule kind",
            name: "bogus".into(),
            expected: "static|dynamic|guided",
        };
        let msg = e.to_string();
        assert!(msg.contains("bogus"), "{msg}");
        assert!(msg.contains("static|dynamic|guided"), "{msg}");
    }

    #[test]
    fn at_line_flattens_nested_registry_errors() {
        let e = PatsmaError::registry("bad hits \"x\"").at_line(5);
        assert_eq!(e.to_string(), "registry line 5: bad hits \"x\"");
        // Non-registry errors keep their full message under the line tag.
        let e = PatsmaError::Invalid("negative cost".into()).at_line(2);
        assert_eq!(e.to_string(), "registry line 2: negative cost");
    }

    #[test]
    fn io_preserves_the_source_chain() {
        use std::error::Error as _;
        let e = PatsmaError::io(
            "reading registry",
            Path::new("/nope"),
            std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        );
        assert!(e.source().is_some());
        assert!(e.to_string().contains("/nope"), "{e}");
    }

    #[test]
    fn anyhow_interop_is_free() {
        fn inner() -> Result<(), PatsmaError> {
            Err(PatsmaError::Draining)
        }
        fn outer() -> anyhow::Result<()> {
            inner()?;
            Ok(())
        }
        let msg = format!("{:#}", outer().unwrap_err());
        assert!(msg.contains("draining"), "{msg}");
    }
}
