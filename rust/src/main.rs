//! `patsma` — the L3 coordinator binary.
//!
//! Self-contained after `make artifacts`: Python never runs on any code
//! path reachable from here.

use patsma::cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match cli::parse(&args)
        .map_err(anyhow::Error::from)
        .and_then(cli::execute)
    {
        Ok(out) => print!("{out}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
