//! [`TunedTable`] — contextual memory of converged tuning results.
//!
//! PATSMA's drift loop re-tunes whenever the landscape shifts, but it has
//! no memory *across* contexts: a region built for a (workload, input
//! size, thread count, environment) combination that was already paid for
//! in an earlier run — or an earlier region — starts cold again. The
//! tuned table closes that loop (ROADMAP open item 2, LibreTune's
//! "AutoTune Live" design): converged cells are keyed by a [`ContextKey`]
//! fingerprint and revisiting a known context costs **zero** tuning
//! iterations.
//!
//! * **Exact hit** — same context fingerprint: the region pins the cell's
//!   point and bypasses immediately ([`crate::tuner::Autotuning::pin`]).
//! * **Near hit** — same context except a neighbouring input-size bucket
//!   (the pow2 lattice of [`ContextKey::bucket_of`]): the cell seeds a
//!   warm start at the region's reduced re-tune budget.
//! * **Miss** — cold tune, then [`TunedTable::observe`] stores the result.
//!
//! Each cell carries a **confidence weight** that grows with confirming
//! observations and an **authority limit**: a single new observation may
//! move a cell by at most `max_move / weight` of each coordinate's scale,
//! so one noisy (or adversarial) sample cannot overwrite a
//! high-confidence cell — while a *sustained* shift erodes the weight and
//! eventually wins. [`SharedTunedTable`] is the thread-safe handle regions
//! hold; the daemon persists cells as registry-v2 `table` records and
//! shares them across processes through the `lookup` / `promote` wire
//! verbs ([`crate::service::Request`]).

use crate::error::PatsmaError;
use crate::service::cache::fnv1a;
use crate::service::registry::{kv_num, kv_num_or, kv_opt, split_kv};
use crate::service::EnvFingerprint;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex};

/// The execution-context fingerprint a tuned cell is keyed by: workload
/// identity, input-size bucket, thread count and environment — the same
/// fields [`crate::service::SessionState`] already persists per session,
/// collapsed into a hashable key.
///
/// # Examples
///
/// ```
/// use patsma::adaptive::ContextKey;
/// use patsma::service::EnvFingerprint;
///
/// let env = EnvFingerprint::with_threads(8);
/// let a = ContextKey::new(0xFEED, 1_000_000, 8, &env);
/// let b = ContextKey::new(0xFEED, 900_000, 8, &env);
/// // Same pow2 size bucket → the same context.
/// assert_eq!(a, b);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ContextKey {
    /// Workload identity (e.g. [`crate::service::cache::fingerprint_str`]
    /// of the workload descriptor).
    pub workload: u64,
    /// Input-size bucket on the pow2 lattice ([`Self::bucket_of`]).
    pub bucket: u32,
    /// Worker threads the region runs under.
    pub threads: u32,
    /// Environment hash ([`EnvFingerprint::hash`]).
    pub env: u64,
    /// Objective-preset code ([`crate::space::ObjectivePreset::code`];
    /// `0` = plain scalar). A cell tuned for "cheapest" must never answer
    /// a "fastest-stable" lookup — the winning cells genuinely differ —
    /// so the objective participates in the context identity.
    pub objective: u32,
}

impl ContextKey {
    /// Key for `workload` (a precomputed fingerprint) at `input_size`
    /// elements under `threads` workers in environment `env`. The input
    /// size lands in its pow2 bucket; size `0` (unknown) lands in bucket 0.
    pub fn new(workload: u64, input_size: u64, threads: usize, env: &EnvFingerprint) -> Self {
        Self {
            workload,
            bucket: Self::bucket_of(input_size),
            threads: threads as u32,
            env: env.hash,
            objective: 0,
        }
    }

    /// The same context under a different objective preset
    /// ([`crate::space::ObjectivePreset::code`]).
    pub fn with_objective(mut self, code: u32) -> Self {
        self.objective = code;
        self
    }

    /// The pow2 lattice bucket of an input size: sizes in
    /// `(2^(k-1), 2^k]` share bucket `k`; sizes 0 and 1 land in bucket 0.
    /// Bucketing is what makes revisits *recognisable* — a 1,000,000-element
    /// problem and a 980,000-element one are the same tuning context.
    pub fn bucket_of(size: u64) -> u32 {
        if size <= 1 {
            0
        } else {
            64 - (size - 1).leading_zeros()
        }
    }

    /// The same context at a different size bucket.
    pub fn with_bucket(mut self, bucket: u32) -> Self {
        self.bucket = bucket;
        self
    }

    /// Neighbouring size buckets (`bucket ± 1`) — the near-hit candidates,
    /// closest first (the smaller bucket is checked before the larger).
    pub fn neighbors(&self) -> Vec<ContextKey> {
        let mut out = Vec::with_capacity(2);
        if self.bucket > 0 {
            out.push(self.with_bucket(self.bucket - 1));
        }
        out.push(self.with_bucket(self.bucket + 1));
        out
    }

    /// The cell index: FNV-1a over every field. Thread count and
    /// environment *participate* in the key — the same workload under a
    /// different pool size is a different context.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(28);
        bytes.extend_from_slice(&self.workload.to_le_bytes());
        bytes.extend_from_slice(&self.bucket.to_le_bytes());
        bytes.extend_from_slice(&self.threads.to_le_bytes());
        bytes.extend_from_slice(&self.env.to_le_bytes());
        bytes.extend_from_slice(&self.objective.to_le_bytes());
        fnv1a(bytes)
    }

    /// The key as `key=value` pairs (registry-v2 / wire codec).
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let mut kv = vec![
            ("workload".into(), self.workload.to_string()),
            ("bucket".into(), self.bucket.to_string()),
            ("threads".into(), self.threads.to_string()),
            ("env".into(), self.env.to_string()),
        ];
        if self.objective != 0 {
            // Scalar cells keep the pre-objective record shape: registries
            // written by this version load byte-identically in older
            // readers as long as only the default objective is in play.
            kv.push(("obj".into(), self.objective.to_string()));
        }
        kv
    }

    /// Parse pairs produced by [`to_kv`](Self::to_kv); unknown keys are
    /// ignored and a missing `obj` means the scalar objective (forward
    /// *and* backward compatibility).
    pub fn from_kv(pairs: &[(String, String)]) -> Result<Self, PatsmaError> {
        Ok(Self {
            workload: kv_num(pairs, "workload")?,
            bucket: kv_num(pairs, "bucket")?,
            threads: kv_num(pairs, "threads")?,
            env: kv_num(pairs, "env")?,
            objective: kv_num_or(pairs, "obj", 0)?,
        })
    }
}

/// One remembered tuning result: the converged point (user domain for
/// numeric regions, unit coordinates for typed spaces), its cost, and the
/// confidence weight the authority limit scales against.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedCell {
    /// The converged parameter vector.
    pub point: Vec<f64>,
    /// The cost measured at the converged point.
    pub cost: f64,
    /// Confirming observations (≥ 1). High weight = tight authority.
    pub weight: u32,
    /// Optional human-readable cell label (typed spaces; display only).
    pub label: Option<String>,
}

/// A keyed cell — the unit of persistence (registry-v2 `table` records)
/// and of the `lookup` / `promote` wire verbs.
#[derive(Debug, Clone, PartialEq)]
pub struct TableEntry {
    /// The execution context the cell answers for.
    pub key: ContextKey,
    /// The remembered result.
    pub cell: TunedCell,
}

impl TableEntry {
    /// The record body as `key=value` pairs (registry-v2 / wire codec).
    pub fn to_kv(&self) -> Vec<(String, String)> {
        let mut kv = self.key.to_kv();
        kv.extend([
            ("point".into(), join_point(&self.cell.point)),
            ("cost".into(), format!("{:.17e}", self.cell.cost)),
            ("weight".into(), self.cell.weight.to_string()),
        ]);
        if let Some(label) = &self.cell.label {
            // Labels travel inside a whitespace-split record body.
            kv.push(("label".into(), label.replace(char::is_whitespace, "_")));
        }
        kv
    }

    /// Parse a record body produced by [`to_kv`](Self::to_kv). Unknown
    /// keys are ignored (forward compatibility).
    pub fn from_kv(pairs: &[(String, String)]) -> Result<Self, PatsmaError> {
        let entry = Self {
            key: ContextKey::from_kv(pairs)?,
            cell: TunedCell {
                point: split_point(kv_opt(pairs, "point").unwrap_or("-"))?,
                cost: kv_num(pairs, "cost")?,
                weight: kv_num::<u32>(pairs, "weight")?.max(1),
                label: kv_opt(pairs, "label").map(str::to_string),
            },
        };
        if entry.cell.point.is_empty() {
            return Err(PatsmaError::registry("table record with empty point"));
        }
        Ok(entry)
    }

    /// The full registry-v2 record line (without trailing newline).
    pub fn to_record(&self) -> String {
        let body: Vec<String> = self
            .to_kv()
            .into_iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        format!("table {}", body.join(" "))
    }

    /// Parse the body tokens of a `table` record line.
    pub fn from_tokens(tokens: &[&str]) -> Result<Self, PatsmaError> {
        Self::from_kv(&split_kv(tokens)?)
    }
}

fn join_point(point: &[f64]) -> String {
    if point.is_empty() {
        return "-".into();
    }
    point
        .iter()
        .map(|v| format!("{v:.17e}"))
        .collect::<Vec<_>>()
        .join(",")
}

fn split_point(text: &str) -> Result<Vec<f64>, PatsmaError> {
    if text == "-" {
        return Ok(Vec::new());
    }
    text.split(',')
        .map(|t| {
            t.parse()
                .map_err(|_| PatsmaError::registry(format!("bad table point coord {t:?}")))
        })
        .collect()
}

/// How far a single observation may move an existing cell.
///
/// The allowance for a cell of weight `w` is `max_move / w` of each
/// coordinate's scale (`max(|coord|, 1)`; for the cost, `|cost|`). A
/// weight-1 cell moves freely (up to `max_move` of its scale per sample);
/// a weight-8 cell barely moves — one poisoned sample cannot drag it off
/// its optimum, while a *sustained* shift erodes the weight one
/// disagreeing sample at a time until the new landscape wins.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TableAuthority {
    /// Fraction of a coordinate's scale a weight-1 cell may move per
    /// observation.
    pub max_move: f64,
    /// Confidence cap — confirmations beyond this stop tightening the
    /// authority (and a cell can always be eroded back down).
    pub max_weight: u32,
}

impl Default for TableAuthority {
    fn default() -> Self {
        Self {
            max_move: 0.25,
            max_weight: 64,
        }
    }
}

impl TableAuthority {
    /// The per-observation movement allowance of a cell at `weight`, as a
    /// fraction of coordinate scale.
    pub fn allowance(&self, weight: u32) -> f64 {
        self.max_move / weight.max(1) as f64
    }
}

/// What [`TunedTable::observe`] did with a sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableUpdate {
    /// First observation for the context: cell created at weight 1.
    Inserted,
    /// The sample agreed with the cell: weight grew.
    Confirmed,
    /// The sample disagreed: the cell moved within its authority
    /// allowance and its weight eroded.
    Adjusted,
    /// The cell's dimensionality changed (new search space): replaced at
    /// weight 1.
    Replaced,
    /// Non-finite or empty sample: dropped.
    Rejected,
}

/// How a region was seeded from the table (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableSeed {
    /// No table, a miss, or an unusable cell: cold start.
    None,
    /// Exact context hit: pinned, zero tuning evaluations.
    Exact,
    /// Neighbouring size bucket: warm start at the re-tune budget.
    Near,
}

/// The tuned table: context-keyed cells under an authority limit. Most
/// callers hold a [`SharedTunedTable`]; this is the single-threaded core.
#[derive(Debug, Clone, Default)]
pub struct TunedTable {
    cells: HashMap<u64, TableEntry>,
    authority: TableAuthority,
}

/// A table lookup outcome (owned — cells are small).
#[derive(Debug, Clone, PartialEq)]
pub enum TableHit {
    /// The exact context is known.
    Exact(TunedCell),
    /// A neighbouring size bucket is known (the key it was found under).
    Near(ContextKey, TunedCell),
    /// Unknown context.
    Miss,
}

impl TunedTable {
    /// An empty table under the default [`TableAuthority`].
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty table under an explicit authority limit.
    pub fn with_authority(authority: TableAuthority) -> Self {
        Self {
            cells: HashMap::new(),
            authority,
        }
    }

    /// The authority limit in force.
    pub fn authority(&self) -> TableAuthority {
        self.authority
    }

    /// Exact cell for `key`, if one is stored.
    pub fn get(&self, key: &ContextKey) -> Option<&TunedCell> {
        self.cells.get(&key.fingerprint()).map(|e| &e.cell)
    }

    /// Exact-hit / near-hit / miss resolution (see module docs): the exact
    /// context first, then the `bucket ± 1` neighbours, closest first.
    pub fn lookup(&self, key: &ContextKey) -> TableHit {
        if let Some(cell) = self.get(key) {
            return TableHit::Exact(cell.clone());
        }
        for neighbor in key.neighbors() {
            if let Some(cell) = self.get(&neighbor) {
                return TableHit::Near(neighbor, cell.clone());
            }
        }
        TableHit::Miss
    }

    /// Fold one converged result into the table under the authority limit
    /// (see [`TableUpdate`] for the outcomes). Non-finite samples are
    /// rejected; a dimensionality change replaces the cell outright.
    pub fn observe(
        &mut self,
        key: ContextKey,
        point: &[f64],
        cost: f64,
        label: Option<&str>,
    ) -> TableUpdate {
        if point.is_empty() || !cost.is_finite() || point.iter().any(|v| !v.is_finite()) {
            return TableUpdate::Rejected;
        }
        let fresh = |weight| TableEntry {
            key,
            cell: TunedCell {
                point: point.to_vec(),
                cost,
                weight,
                label: label.map(str::to_string),
            },
        };
        let Some(entry) = self.cells.get_mut(&key.fingerprint()) else {
            self.cells.insert(key.fingerprint(), fresh(1));
            return TableUpdate::Inserted;
        };
        if entry.cell.point.len() != point.len() {
            *entry = fresh(1);
            return TableUpdate::Replaced;
        }
        let allowance = self.authority.allowance(entry.cell.weight);
        let agrees = entry
            .cell
            .point
            .iter()
            .zip(point)
            .all(|(a, b)| (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0));
        // The cost always tracks within authority — even a confirming
        // sample re-measures it (machines drift too).
        entry.cell.cost += clamp_move(cost - entry.cell.cost, allowance * entry.cell.cost.abs());
        if agrees {
            entry.cell.weight = (entry.cell.weight + 1).min(self.authority.max_weight);
            if let Some(label) = label {
                entry.cell.label = Some(label.to_string());
            }
            TableUpdate::Confirmed
        } else {
            for (cur, &new) in entry.cell.point.iter_mut().zip(point) {
                *cur += clamp_move(new - *cur, allowance * cur.abs().max(1.0));
            }
            entry.cell.weight = entry.cell.weight.saturating_sub(1).max(1);
            TableUpdate::Adjusted
        }
    }

    /// Merge a full entry (wire `promote`, registry load): the higher
    /// weight wins, ties break toward the lower cost. Returns the weight
    /// of the cell now stored for the context.
    pub fn promote(&mut self, entry: TableEntry) -> Result<u32, PatsmaError> {
        if entry.cell.point.is_empty()
            || !entry.cell.cost.is_finite()
            || entry.cell.point.iter().any(|v| !v.is_finite())
        {
            return Err(PatsmaError::registry("promoted cell must be finite"));
        }
        let mut entry = entry;
        entry.cell.weight = entry.cell.weight.clamp(1, self.authority.max_weight);
        let slot = self.cells.entry(entry.key.fingerprint());
        let kept = slot
            .and_modify(|cur| {
                let wins = entry.cell.weight > cur.cell.weight
                    || (entry.cell.weight == cur.cell.weight && entry.cell.cost < cur.cell.cost);
                if wins {
                    *cur = entry.clone();
                }
            })
            .or_insert_with(|| entry.clone());
        Ok(kept.cell.weight)
    }

    /// Merge every entry (registry seeding); invalid cells are skipped.
    pub fn load(&mut self, entries: &[TableEntry]) {
        for entry in entries {
            let _ = self.promote(entry.clone());
        }
    }

    /// Every cell, sorted by key fields (stable snapshot order).
    pub fn entries(&self) -> Vec<TableEntry> {
        let mut out: Vec<TableEntry> = self.cells.values().cloned().collect();
        out.sort_by_key(|e| (e.key.workload, e.key.bucket, e.key.threads, e.key.env));
        out
    }

    /// Stored cell count.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// True when no cells are stored.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Drop every cell.
    pub fn clear(&mut self) {
        self.cells.clear();
    }
}

fn clamp_move(delta: f64, limit: f64) -> f64 {
    delta.clamp(-limit.abs(), limit.abs())
}

/// The thread-safe tuned-table handle regions and the daemon hold
/// (cheaply cloneable; all clones share the cells).
///
/// # Examples
///
/// ```
/// use patsma::adaptive::{ContextKey, SharedTunedTable, TableHit};
/// use patsma::service::EnvFingerprint;
///
/// let table = SharedTunedTable::new();
/// let key = ContextKey::new(7, 4096, 8, &EnvFingerprint::with_threads(8));
/// table.observe(key, &[48.0], 0.25, None);
/// assert!(matches!(table.lookup(&key), TableHit::Exact(_)));
/// ```
#[derive(Clone, Default)]
pub struct SharedTunedTable(Arc<Mutex<TunedTable>>);

impl SharedTunedTable {
    /// An empty shared table under the default authority.
    pub fn new() -> Self {
        Self::default()
    }

    /// An empty shared table under an explicit authority limit.
    pub fn with_authority(authority: TableAuthority) -> Self {
        Self(Arc::new(Mutex::new(TunedTable::with_authority(authority))))
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TunedTable> {
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// See [`TunedTable::lookup`].
    pub fn lookup(&self, key: &ContextKey) -> TableHit {
        self.lock().lookup(key)
    }

    /// See [`TunedTable::get`] (owned).
    pub fn get(&self, key: &ContextKey) -> Option<TunedCell> {
        self.lock().get(key).cloned()
    }

    /// See [`TunedTable::observe`].
    pub fn observe(
        &self,
        key: ContextKey,
        point: &[f64],
        cost: f64,
        label: Option<&str>,
    ) -> TableUpdate {
        self.lock().observe(key, point, cost, label)
    }

    /// See [`TunedTable::promote`].
    pub fn promote(&self, entry: TableEntry) -> Result<u32, PatsmaError> {
        self.lock().promote(entry)
    }

    /// See [`TunedTable::load`].
    pub fn load(&self, entries: &[TableEntry]) {
        self.lock().load(entries)
    }

    /// See [`TunedTable::entries`].
    pub fn entries(&self) -> Vec<TableEntry> {
        self.lock().entries()
    }

    /// See [`TunedTable::len`].
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// See [`TunedTable::is_empty`].
    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// See [`TunedTable::clear`].
    pub fn clear(&self) {
        self.lock().clear()
    }
}

impl fmt::Debug for SharedTunedTable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SharedTunedTable")
            .field("cells", &self.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(workload: u64, size: u64) -> ContextKey {
        ContextKey::new(workload, size, 8, &EnvFingerprint::with_threads(8))
    }

    #[test]
    fn pow2_buckets_partition_sizes() {
        assert_eq!(ContextKey::bucket_of(0), 0);
        assert_eq!(ContextKey::bucket_of(1), 0);
        assert_eq!(ContextKey::bucket_of(2), 1);
        assert_eq!(ContextKey::bucket_of(3), 2);
        assert_eq!(ContextKey::bucket_of(4), 2);
        assert_eq!(ContextKey::bucket_of(5), 3);
        assert_eq!(ContextKey::bucket_of(1 << 20), 20);
        assert_eq!(ContextKey::bucket_of((1 << 20) + 1), 21);
    }

    #[test]
    fn fingerprint_distinguishes_every_field() {
        let env = EnvFingerprint::with_threads(8);
        let base = ContextKey::new(1, 1024, 8, &env);
        let fp = base.fingerprint();
        assert_ne!(ContextKey::new(2, 1024, 8, &env).fingerprint(), fp);
        assert_ne!(ContextKey::new(1, 4096, 8, &env).fingerprint(), fp);
        assert_ne!(ContextKey::new(1, 1024, 4, &env).fingerprint(), fp);
        let other_env = EnvFingerprint::with_threads(16);
        assert_ne!(ContextKey::new(1, 1024, 8, &other_env).fingerprint(), fp);
        assert_ne!(base.with_objective(1).fingerprint(), fp);
    }

    #[test]
    fn objective_separates_cells_and_roundtrips_leniently() {
        let mut t = TunedTable::new();
        let scalar = key(7, 4096);
        let stable = scalar.with_objective(1);
        t.observe(scalar, &[8.0], 1.0, None);
        t.observe(stable, &[64.0], 2.0, None);
        assert_eq!(t.get(&scalar).unwrap().point, vec![8.0]);
        assert_eq!(t.get(&stable).unwrap().point, vec![64.0]);
        // Scalar keys keep the legacy record shape; objective keys add obj=.
        let records: Vec<String> = t.entries().iter().map(TableEntry::to_record).collect();
        assert!(records.iter().any(|r| !r.contains("obj=")));
        assert!(records.iter().any(|r| r.contains("obj=1")));
        for line in &records {
            let tokens: Vec<&str> = line.split_whitespace().collect();
            let parsed = TableEntry::from_tokens(&tokens[1..]).unwrap();
            assert!(t.get(&parsed.key).is_some(), "roundtrip lost {line:?}");
        }
        // A legacy record without obj= parses as the scalar objective.
        let legacy = ContextKey::from_kv(&[
            ("workload".into(), "7".into()),
            ("bucket".into(), "12".into()),
            ("threads".into(), "8".into()),
            ("env".into(), "3".into()),
        ])
        .unwrap();
        assert_eq!(legacy.objective, 0);
    }

    #[test]
    fn observe_insert_confirm_and_erode() {
        let mut t = TunedTable::new();
        let k = key(1, 4096);
        assert_eq!(t.observe(k, &[48.0], 1.0, None), TableUpdate::Inserted);
        assert_eq!(t.get(&k).unwrap().weight, 1);
        for expect in 2..=5u32 {
            assert_eq!(t.observe(k, &[48.0], 1.0, None), TableUpdate::Confirmed);
            assert_eq!(t.get(&k).unwrap().weight, expect);
        }
        // A disagreeing sample erodes the weight and barely moves the cell.
        assert_eq!(t.observe(k, &[120.0], 1.0, None), TableUpdate::Adjusted);
        let cell = t.get(&k).unwrap();
        assert_eq!(cell.weight, 4);
        let allowed = t.authority().allowance(5) * 48.0;
        assert!(
            (cell.point[0] - 48.0).abs() <= allowed + 1e-12,
            "moved {} > allowance {allowed}",
            (cell.point[0] - 48.0).abs()
        );
    }

    #[test]
    fn non_finite_samples_are_rejected() {
        let mut t = TunedTable::new();
        let k = key(2, 64);
        assert_eq!(t.observe(k, &[f64::NAN], 1.0, None), TableUpdate::Rejected);
        assert_eq!(
            t.observe(k, &[1.0], f64::INFINITY, None),
            TableUpdate::Rejected
        );
        assert_eq!(t.observe(k, &[], 1.0, None), TableUpdate::Rejected);
        assert!(t.is_empty());
    }

    #[test]
    fn dimension_change_replaces_the_cell() {
        let mut t = TunedTable::new();
        let k = key(3, 64);
        t.observe(k, &[1.0], 1.0, None);
        assert_eq!(t.observe(k, &[1.0, 2.0], 0.5, None), TableUpdate::Replaced);
        let cell = t.get(&k).unwrap();
        assert_eq!(cell.point.len(), 2);
        assert_eq!(cell.weight, 1);
    }

    #[test]
    fn lookup_prefers_exact_then_nearest_bucket() {
        let mut t = TunedTable::new();
        let k = key(4, 1 << 10);
        t.observe(k.with_bucket(k.bucket - 1), &[10.0], 1.0, None);
        t.observe(k.with_bucket(k.bucket + 1), &[20.0], 1.0, None);
        match t.lookup(&k) {
            TableHit::Near(found, cell) => {
                assert_eq!(found.bucket, k.bucket - 1, "smaller bucket first");
                assert_eq!(cell.point, vec![10.0]);
            }
            other => panic!("expected near hit, got {other:?}"),
        }
        t.observe(k, &[15.0], 0.5, None);
        assert!(matches!(t.lookup(&k), TableHit::Exact(_)));
        // A context two buckets away is a miss.
        assert_eq!(t.lookup(&key(4, 1 << 14)), TableHit::Miss);
    }

    #[test]
    fn promote_keeps_the_higher_confidence_cell() {
        let mut t = TunedTable::new();
        let k = key(5, 256);
        let entry = |weight, cost| TableEntry {
            key: k,
            cell: TunedCell {
                point: vec![7.0],
                cost,
                weight,
                label: None,
            },
        };
        assert_eq!(t.promote(entry(3, 1.0)).unwrap(), 3);
        // Lower weight loses.
        assert_eq!(t.promote(entry(2, 0.1)).unwrap(), 3);
        assert_eq!(t.get(&k).unwrap().cost, 1.0);
        // Equal weight, better cost wins.
        assert_eq!(t.promote(entry(3, 0.5)).unwrap(), 3);
        assert_eq!(t.get(&k).unwrap().cost, 0.5);
        // Higher weight wins outright.
        assert_eq!(t.promote(entry(9, 2.0)).unwrap(), 9);
        assert!(t.promote(entry(1, f64::NAN)).is_err());
    }

    #[test]
    fn entries_roundtrip_through_the_record_codec() {
        let mut t = TunedTable::new();
        t.observe(key(9, 4096), &[48.0, 0.5], 0.125, Some("dynamic,chunk=48"));
        t.observe(key(1, 64), &[3.0], 2.5, None);
        for entry in t.entries() {
            let line = entry.to_record();
            let tokens: Vec<&str> = line.split_whitespace().collect();
            assert_eq!(tokens[0], "table");
            let parsed = TableEntry::from_tokens(&tokens[1..]).unwrap();
            assert_eq!(parsed, entry);
        }
        // Sorted by key fields.
        let keys: Vec<u64> = t.entries().iter().map(|e| e.key.workload).collect();
        assert_eq!(keys, vec![1, 9]);
    }

    #[test]
    fn shared_table_is_cloneable_and_consistent() {
        let table = SharedTunedTable::new();
        let clone = table.clone();
        let k = key(6, 512);
        table.observe(k, &[4.0], 1.0, None);
        assert_eq!(clone.len(), 1);
        assert!(matches!(clone.lookup(&k), TableHit::Exact(_)));
        clone.clear();
        assert!(table.is_empty());
    }
}
