//! Workload-drift detection over a rolling cost window.
//!
//! After a [`super::TunedRegion`] converges it keeps running the final
//! solution at zero optimizer overhead (the paper's Fig. 1 "bypass"). But
//! the bypass is only as good as the context it was tuned under: if the
//! workload shifts — problem size grows, a co-tenant steals cores, the
//! matrix gets denser — the frozen parameter silently decays from optimal
//! to arbitrary. [`DriftMonitor`] watches the bypass costs and says *when*
//! that has happened, so the region can trigger a warm re-tune (cf. HPX
//! Smart Executors' runtime chunk re-selection and Karcher & Guckes'
//! self-adaptive concurrency libraries).
//!
//! ## Detection rule
//!
//! The monitor first accumulates `window` finite samples into a baseline
//! (streaming mean/variance via [`crate::stats::Welford`]), then tracks an
//! EWMA of subsequent costs and flags drift when the EWMA leaves the band
//!
//! ```text
//! |ewma − baseline_mean| > threshold_sigma · baseline_stddev
//!                          + rel_margin · |baseline_mean|
//! ```
//!
//! The two band terms cover the two failure modes of a pure z-score test:
//! * `threshold_sigma · stddev` adapts to noisy workloads — a jittery cost
//!   stream needs a wide band or every scheduler hiccup would retrigger
//!   tuning;
//! * `rel_margin · |mean|` keeps a *constant* (zero-variance) stream from
//!   producing false positives: with `stddev == 0` any epsilon deviation
//!   would otherwise be an infinite z-score.
//!
//! Non-finite costs (NaN/Inf — a timer glitch, a cost overflow) are
//! rejected outright: they never enter the baseline, never move the EWMA
//! and never signal drift; they are only counted in
//! [`rejected`](DriftMonitor::rejected).
//!
//! # Examples
//!
//! ```
//! use patsma::adaptive::{DriftConfig, DriftMonitor};
//!
//! let mut m = DriftMonitor::new(DriftConfig::default());
//! // Stable phase: prime the baseline, no drift.
//! for _ in 0..20 {
//!     assert!(!m.observe(1.0));
//! }
//! // The workload shifts: costs triple — drift within a few samples.
//! let fired = (0..10).any(|_| m.observe(3.0));
//! assert!(fired);
//! ```

use crate::stats::Welford;

/// Tuning knobs of a [`DriftMonitor`].
///
/// # Examples
///
/// ```
/// let cfg = patsma::adaptive::DriftConfig::default();
/// assert!(cfg.window >= 1 && cfg.alpha > 0.0 && cfg.alpha <= 1.0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftConfig {
    /// Finite samples that establish the baseline before detection starts
    /// (values `< 1` are treated as `1`).
    pub window: usize,
    /// EWMA smoothing factor in `(0, 1]`: higher reacts faster but is more
    /// sensitive to single-sample noise.
    pub alpha: f64,
    /// Band half-width in baseline standard deviations.
    pub threshold_sigma: f64,
    /// Band floor as a fraction of `|baseline mean|` — the constant-stream
    /// guard (see module docs).
    pub rel_margin: f64,
}

impl Default for DriftConfig {
    /// `window = 8`, `alpha = 0.3`, `threshold_sigma = 4`, `rel_margin =
    /// 0.2`: detects a sustained ≳20% cost shift within a handful of
    /// iterations while riding out one-off scheduler spikes.
    fn default() -> Self {
        Self {
            window: 8,
            alpha: 0.3,
            threshold_sigma: 4.0,
            rel_margin: 0.2,
        }
    }
}

impl DriftConfig {
    /// Builder-style baseline window override.
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window;
        self
    }

    /// Builder-style band override (`threshold_sigma`, `rel_margin`).
    pub fn with_band(mut self, threshold_sigma: f64, rel_margin: f64) -> Self {
        self.threshold_sigma = threshold_sigma;
        self.rel_margin = rel_margin;
        self
    }
}

/// EWMA-vs-baseline drift detector (see module docs).
///
/// `observe` keeps returning `true` while the EWMA sits outside the band;
/// callers that act on drift (e.g. [`super::TunedRegion`]) should
/// [`reset`](DriftMonitor::reset) the monitor when they do, so a fresh
/// baseline forms under the new conditions.
///
/// # Examples
///
/// ```
/// use patsma::adaptive::{DriftConfig, DriftMonitor};
///
/// let mut m = DriftMonitor::new(DriftConfig::default().with_window(4));
/// for _ in 0..4 {
///     m.observe(2.0);
/// }
/// assert!(m.is_primed());
/// assert_eq!(m.baseline_mean(), 2.0);
/// ```
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    cfg: DriftConfig,
    baseline: Welford,
    ewma: Option<f64>,
    observed: u64,
    rejected: u64,
}

impl DriftMonitor {
    /// A monitor with an empty baseline.
    pub fn new(cfg: DriftConfig) -> Self {
        Self {
            cfg,
            baseline: Welford::new(),
            ewma: None,
            observed: 0,
            rejected: 0,
        }
    }

    /// Feed one cost sample; `true` means the stream has drifted from the
    /// baseline. Non-finite samples are rejected (never drift, never enter
    /// any statistic except [`rejected`](Self::rejected)).
    pub fn observe(&mut self, cost: f64) -> bool {
        if !cost.is_finite() {
            self.rejected += 1;
            return false;
        }
        self.observed += 1;
        if (self.baseline.count() as usize) < self.cfg.window.max(1) {
            self.baseline.push(cost);
            return false;
        }
        let prev = self.ewma.unwrap_or_else(|| self.baseline.mean());
        let e = self.cfg.alpha * cost + (1.0 - self.cfg.alpha) * prev;
        self.ewma = Some(e);
        let band = self.cfg.threshold_sigma * self.baseline.stddev()
            + self.cfg.rel_margin * self.baseline.mean().abs();
        (e - self.baseline.mean()).abs() > band
    }

    /// Discard the baseline and EWMA so a new baseline forms from the next
    /// samples (call after acting on a drift signal). Sample counters are
    /// retained as a lifetime record.
    pub fn reset(&mut self) {
        self.baseline = Welford::new();
        self.ewma = None;
    }

    /// True once the baseline window is full and detection is active.
    pub fn is_primed(&self) -> bool {
        (self.baseline.count() as usize) >= self.cfg.window.max(1)
    }

    /// Baseline mean (0 while the baseline is empty).
    pub fn baseline_mean(&self) -> f64 {
        self.baseline.mean()
    }

    /// Baseline sample standard deviation.
    pub fn baseline_stddev(&self) -> f64 {
        self.baseline.stddev()
    }

    /// Current EWMA (`None` until the first post-baseline sample).
    pub fn ewma(&self) -> Option<f64> {
        self.ewma
    }

    /// Finite samples seen over the monitor's lifetime (survives `reset`).
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Non-finite samples rejected over the monitor's lifetime.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// The active configuration.
    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(window: usize) -> DriftMonitor {
        DriftMonitor::new(DriftConfig::default().with_window(window))
    }

    #[test]
    fn constant_stream_never_false_positives() {
        let mut m = monitor(8);
        for i in 0..10_000 {
            assert!(!m.observe(3.25), "false positive at sample {i}");
        }
        assert_eq!(m.observed(), 10_000);
    }

    #[test]
    fn constant_zero_stream_never_false_positives() {
        // mean == 0 makes the rel_margin term vanish too; the band is then
        // exactly 0 and the EWMA sits exactly on the mean.
        let mut m = monitor(4);
        for _ in 0..1000 {
            assert!(!m.observe(0.0));
        }
    }

    #[test]
    fn single_sample_window_works() {
        let mut m = monitor(1);
        assert!(!m.observe(10.0)); // the whole baseline
        assert!(m.is_primed());
        // Small wobble within the 20% margin: quiet.
        assert!(!m.observe(10.5));
        // Sustained 3x shift: fires.
        let fired = (0..20).any(|_| m.observe(30.0));
        assert!(fired);
    }

    #[test]
    fn zero_window_is_promoted_to_one() {
        let mut m = monitor(0);
        assert!(!m.observe(5.0));
        assert!(m.is_primed());
    }

    #[test]
    fn nan_and_inf_are_rejected_everywhere() {
        let mut m = monitor(3);
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert!(!m.observe(bad));
        }
        assert_eq!(m.rejected(), 3);
        assert_eq!(m.observed(), 0);
        assert!(!m.is_primed(), "rejected samples must not fill the window");
        // Baseline then forms from finite samples only.
        for _ in 0..3 {
            assert!(!m.observe(2.0));
        }
        assert!(m.is_primed());
        assert_eq!(m.baseline_mean(), 2.0);
        // NaN after priming: still rejected, EWMA untouched.
        assert!(!m.observe(f64::NAN));
        assert_eq!(m.ewma(), None);
        assert!(!m.observe(2.0));
        assert_eq!(m.rejected(), 4);
    }

    #[test]
    fn sustained_shift_is_detected_spike_is_not() {
        let mut m = DriftMonitor::new(DriftConfig {
            window: 8,
            alpha: 0.3,
            threshold_sigma: 4.0,
            rel_margin: 0.2,
        });
        for _ in 0..8 {
            assert!(!m.observe(1.0));
        }
        // One 2x spike: EWMA moves to 1.3, band is 0.2 — briefly out, but a
        // single spike decays back. Use a wider margin to show the intent:
        // the spike is *absorbed* within a couple of quiet samples.
        let spike = m.observe(2.0);
        let mut recovered = true;
        for _ in 0..10 {
            recovered = !m.observe(1.0);
        }
        assert!(recovered, "EWMA must decay back after a lone spike");
        let _ = spike;
        // A sustained doubling keeps the EWMA out of the band.
        let mut fired = false;
        for _ in 0..10 {
            fired |= m.observe(2.0);
        }
        assert!(fired);
    }

    #[test]
    fn reset_forms_a_new_baseline() {
        let mut m = monitor(4);
        for _ in 0..4 {
            m.observe(1.0);
        }
        assert!((0..10).any(|_| m.observe(5.0)));
        m.reset();
        assert!(!m.is_primed());
        // The new level becomes the new normal.
        for _ in 0..4 {
            assert!(!m.observe(5.0));
        }
        for i in 0..100 {
            assert!(!m.observe(5.0), "false positive after reset at {i}");
        }
        assert!(m.observed() > 100, "lifetime counter survives reset");
    }

    #[test]
    fn noisy_stream_widens_the_band() {
        // Alternating 1.0 / 2.0 baseline: stddev ≈ 0.52, band ≈ 2.1 + 0.3.
        // The same absolute shift that fires on a constant stream stays
        // quiet here.
        let mut m = monitor(8);
        for i in 0..8 {
            m.observe(if i % 2 == 0 { 1.0 } else { 2.0 });
        }
        for i in 0..50 {
            assert!(
                !m.observe(if i % 2 == 0 { 1.2 } else { 2.2 }),
                "noise-level wobble must not fire (sample {i})"
            );
        }
    }
}
