//! [`TunedRegion`] — the online adaptive tuning handle for one hot
//! parallel region.
//!
//! The lifecycle (one `run` call = one application iteration):
//!
//! 1. **Tuning** — candidates flow through the paper's Single-Iteration
//!    protocol ([`crate::tuner::Autotuning::single_exec`]): every call runs
//!    exactly one real application iteration, so tuning adds zero extra
//!    target work.
//! 2. **Bypass** — once the optimizer ends, `run` keeps executing the
//!    converged parameters at zero optimizer overhead, while a
//!    [`DriftMonitor`] baselines the converged cost and watches for a
//!    workload shift.
//! 3. **Warm re-tune** — on drift the region snapshots the optimizer
//!    ([`crate::optimizer::OptimizerState`]), rebuilds it at a *reduced*
//!    budget (the [`TunedRegionConfig`] `retune_budget_pct`) and warm-starts
//!    it from the snapshot with [`crate::optimizer::ResetLevel::Soft`]
//!    semantics: persisted solutions are kept as starting material, stale
//!    costs are re-measured. The region is back in state 1 — with strictly
//!    fewer evaluations to spend than a cold restart.

use super::drift::{DriftConfig, DriftMonitor};
use super::table::{ContextKey, SharedTunedTable, TableHit, TableSeed};
use crate::optimizer::OptimizerState;
use crate::service::OptimizerSpec;
use crate::space::{CostVector, Dim, MultiObjective, ObjectiveSpec, ParetoFront, Point, SearchSpace};
use crate::tuner::{Autotuning, PointValue, Sample};
use crate::workloads::Workload;
use std::time::Instant;

/// Encode a user-domain point into the optimizer's internal `[-1, 1]^d`
/// box (the inverse of [`crate::tuner::rescale_internal`]); degenerate
/// `lo == hi` dimensions map to the centre.
fn encode_box(point: &[f64], lo: &[f64], hi: &[f64]) -> Vec<f64> {
    point
        .iter()
        .zip(lo.iter().zip(hi))
        .map(|(&v, (&l, &h))| {
            if h > l {
                (2.0 * (v - l) / (h - l) - 1.0).clamp(-1.0, 1.0)
            } else {
                0.0
            }
        })
        .collect()
}

/// Everything needed to build (and, on drift, rebuild) a region's
/// optimizer: domain, budget, seed, drift policy.
///
/// The domain is a typed [`SearchSpace`]. The paper's single-int chunk API
/// is a thin constructor over it ([`new`](Self::new) /
/// [`with_bounds`](Self::with_bounds) build plain float-box dimensions and
/// [`build`](Self::build) hands the box to the numeric [`TunedRegion`]);
/// mixed spaces — categorical schedule kinds, power-of-two chunks — go
/// through [`with_space`](Self::with_space) + [`build_typed`](Self::build_typed).
///
/// # Examples
///
/// ```
/// use patsma::adaptive::TunedRegionConfig;
///
/// let region = TunedRegionConfig::new(1.0, 128.0)
///     .budget(4, 8)
///     .seed(7)
///     .build::<i32>();
/// assert!(!region.is_converged());
/// ```
#[derive(Debug, Clone)]
pub struct TunedRegionConfig {
    /// The typed parameter domain.
    pub space: SearchSpace,
    /// Stabilisation iterations per measured candidate (paper §2.3).
    pub ignore: u32,
    /// Which optimizer drives the search.
    pub optimizer: OptimizerSpec,
    /// Optimizer population size (`num_opt`).
    pub num_opt: usize,
    /// Optimizer iteration budget (`max_iter`) of a cold start.
    pub max_iter: usize,
    /// RNG seed (re-tunes derive their own seeds from it).
    pub seed: u64,
    /// Drift-detection policy for the bypass phase.
    pub drift: DriftConfig,
    /// Percent of `max_iter` a warm re-tune (or a tuned-table near-hit
    /// warm start) gets, **contractually `1..=100`** — a warm budget can
    /// never exceed the cold budget (min 2 iterations: the re-measure of
    /// the persisted best plus at least one refinement). The
    /// [`retune_budget_pct`](Self::retune_budget_pct) builder clamps;
    /// values poked directly into the field are clamped again at use.
    pub retune_budget_pct: u32,
    /// Optional tuned-table wiring ([`table`](Self::table)): consult the
    /// shared table under this context key before tuning, store the
    /// converged cell after.
    pub table: Option<(SharedTunedTable, ContextKey)>,
    /// What "best" means: the scalarization preset/weights applied to
    /// [`CostVector`] measurements fed through
    /// [`TunedSpace::run_with_cost_vector`]. Plain scalar costs are
    /// unaffected (the default [`ObjectiveSpec`] is the identity on them).
    pub objective: ObjectiveSpec,
}

impl TunedRegionConfig {
    /// One tuned parameter over `[lo, hi]` with the defaults: CSA, 4 × 8
    /// budget, `ignore = 0`, default drift policy, 50% re-tune budget.
    pub fn new(lo: f64, hi: f64) -> Self {
        Self::with_bounds(vec![lo], vec![hi])
    }

    /// Multi-parameter constructor (per-dimension bounds) — e.g. chunk size
    /// × tile size, or the paper's two-colour chunk pair.
    pub fn with_bounds(lo: Vec<f64>, hi: Vec<f64>) -> Self {
        assert_eq!(lo.len(), hi.len(), "bounds length mismatch");
        assert!(!lo.is_empty(), "at least one tuned parameter");
        Self::with_space(SearchSpace::new(
            lo.into_iter()
                .zip(hi)
                .map(|(l, h)| Dim::Float { lo: l, hi: h })
                .collect(),
        ))
    }

    /// Config over a registry workload's typed domain: its
    /// [`Workload::space`] (plain parameters), or — when `joint` — its
    /// [`Workload::joint_space`], the `(schedule kind, chunk, …)` surface.
    /// Build with [`build_typed`](Self::build_typed) and drive with
    /// [`TunedSpace::run_workload`].
    pub fn for_workload(workload: &dyn Workload, joint: bool) -> Self {
        Self::with_space(if joint {
            workload.joint_space()
        } else {
            workload.space()
        })
    }

    /// Typed-domain constructor: tune over any [`SearchSpace`] (integer,
    /// power-of-two, float, log-float and categorical dimensions). Build
    /// with [`build_typed`](Self::build_typed).
    pub fn with_space(space: SearchSpace) -> Self {
        Self {
            space,
            ignore: 0,
            optimizer: OptimizerSpec::Csa,
            num_opt: 4,
            max_iter: 8,
            seed: 42,
            drift: DriftConfig::default(),
            retune_budget_pct: 50,
            table: None,
            objective: ObjectiveSpec::default(),
        }
    }

    /// Builder-style optimizer override.
    pub fn optimizer(mut self, opt: OptimizerSpec) -> Self {
        self.optimizer = opt;
        self
    }

    /// Builder-style budget override.
    pub fn budget(mut self, num_opt: usize, max_iter: usize) -> Self {
        self.num_opt = num_opt.max(1);
        self.max_iter = max_iter;
        self
    }

    /// Builder-style stabilisation-iteration override.
    pub fn ignore(mut self, ignore: u32) -> Self {
        self.ignore = ignore;
        self
    }

    /// Builder-style seed override.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder-style drift-policy override.
    pub fn drift(mut self, drift: DriftConfig) -> Self {
        self.drift = drift;
        self
    }

    /// Builder-style re-tune budget override (percent of `max_iter`),
    /// clamped to `1..=100`: a warm re-tune exists to be *cheaper* than a
    /// cold start, so a percentage above 100 (which would silently grant
    /// the re-tune a larger budget than the cold tune) saturates at 100,
    /// and 0 raises to 1 (the minimum-2-iterations floor still applies).
    pub fn retune_budget_pct(mut self, pct: u32) -> Self {
        self.retune_budget_pct = pct.clamp(1, 100);
        self
    }

    /// Builder-style objective override: which scalarization the region
    /// applies to vector-valued costs
    /// ([`TunedSpace::run_with_cost_vector`]).
    pub fn objective(mut self, spec: ObjectiveSpec) -> Self {
        self.objective = spec;
        self
    }

    /// Builder-style tuned-table wiring: before tuning, consult `table`
    /// under `key` — an exact context hit bypasses immediately with zero
    /// evaluations, a neighbouring size bucket warm-starts at the re-tune
    /// budget, a miss tunes cold; every convergence stores its cell back
    /// ([`super::table`] module docs).
    pub fn table(mut self, table: SharedTunedTable, key: ContextKey) -> Self {
        self.table = Some((table, key));
        self
    }

    /// Iterations a warm start gets: `retune_budget_pct`% of `max_iter`
    /// (percent clamped to `1..=100`), floored at 2.
    fn warm_budget(&self) -> usize {
        let pct = self.retune_budget_pct.clamp(1, 100) as usize;
        ((self.max_iter * pct) / 100).max(2)
    }

    /// Number of tuned parameters.
    pub fn dim(&self) -> usize {
        self.space.dim()
    }

    /// The numeric box `(lo, hi)` of the space; panics for mixed spaces
    /// (those go through [`build_typed`](Self::build_typed)).
    fn numeric_bounds(&self) -> (Vec<f64>, Vec<f64>) {
        self.space.numeric_bounds().expect(
            "this space has pow2/log/categorical dimensions; \
             build it with build_typed instead of build",
        )
    }

    /// Resolve the tuned table into a ready [`Autotuning`]: exact hit →
    /// pinned bypass (zero evaluations), near hit → warm start at the
    /// re-tune budget, miss / no table / unusable cell → cold start at
    /// full budget. Returns the tuner, how it was seeded and — when
    /// pinned — the cell's user-domain point.
    fn seeded_autotuning(&self, lo: &[f64], hi: &[f64]) -> (Autotuning, TableSeed, Option<Vec<f64>>) {
        let dim = lo.len();
        let cold = |iters: usize| {
            let opt = self.optimizer.build(dim, self.num_opt, iters, self.seed);
            Autotuning::with_optimizer(lo.to_vec(), hi.to_vec(), self.ignore, opt)
        };
        let Some((table, key)) = &self.table else {
            return (cold(self.max_iter), TableSeed::None, None);
        };
        match table.lookup(key) {
            TableHit::Exact(cell) if cell.point.len() == dim => {
                let mut at = cold(self.max_iter);
                at.pin(encode_box(&cell.point, lo, hi));
                (at, TableSeed::Exact, Some(cell.point))
            }
            TableHit::Near(_, cell) if cell.point.len() == dim => {
                let internal = encode_box(&cell.point, lo, hi);
                let mut opt = self
                    .optimizer
                    .build(dim, self.num_opt, self.warm_budget(), self.seed);
                let snapshot = OptimizerState {
                    optimizer: opt.name().to_string(),
                    best_internal: internal.clone(),
                    best_cost: cell.cost,
                    temperatures: None,
                    points: vec![internal],
                };
                if opt.warm_start(&snapshot) {
                    let at = Autotuning::with_optimizer(lo.to_vec(), hi.to_vec(), self.ignore, opt);
                    (at, TableSeed::Near, None)
                } else {
                    // The optimizer cannot consume a snapshot (grid): a
                    // reduced budget would just be a worse cold start.
                    (cold(self.max_iter), TableSeed::None, None)
                }
            }
            _ => (cold(self.max_iter), TableSeed::None, None),
        }
    }

    /// Materialise the region (generation 0 = cold start at full budget,
    /// unless a wired tuned table answers for the context — see
    /// [`table`](Self::table)). Requires a numeric box space (the
    /// `new`/`with_bounds` constructors); use
    /// [`build_typed`](Self::build_typed) for mixed spaces.
    pub fn build<P: PointValue>(mut self) -> TunedRegion<P> {
        // A cell tuned under one objective must not answer lookups made
        // under another — the winning cells genuinely differ — so a
        // non-scalar objective folds its preset code into the wired
        // table's context key (regardless of builder-call order).
        if !self.objective.is_scalar() {
            if let Some((_, key)) = &mut self.table {
                *key = key.with_objective(self.objective.preset.code());
            }
        }
        let (lo, hi) = self.numeric_bounds();
        let (at, seeded, pinned) = self.seeded_autotuning(&lo, &hi);
        let monitor = DriftMonitor::new(self.drift);
        let point = pinned
            .as_deref()
            .unwrap_or(&lo)
            .iter()
            .map(|&v| P::from_f64(v))
            .collect();
        TunedRegion {
            point,
            cfg: self,
            at,
            monitor,
            generation: 0,
            evals_prior: 0,
            iterations: 0,
            last_retune_warm: false,
            seeded,
        }
    }

    /// Materialise a **typed** region over the full space: the application
    /// receives decoded [`Point`]s (categorical kinds by bin, pow2/log
    /// dimensions quantized in exponent space). Same lifecycle as
    /// [`TunedRegion`] — tune live, bypass when converged, warm re-tune on
    /// drift.
    pub fn build_typed(self) -> TunedSpace {
        let space = self.space.clone();
        let dim = space.dim();
        let objective = self.objective;
        // The inner numeric region stages the optimizer over the unit
        // hypercube; every candidate decodes through the typed space.
        let unit_cfg = Self {
            space: SearchSpace::unit(dim),
            ..self
        };
        let inner = unit_cfg.build::<f64>();
        // A table-pinned inner region already sits on the remembered unit
        // cell; decode whatever it starts at.
        let point = space.decode_unit(inner.point());
        TunedSpace {
            space,
            inner,
            point,
            mo: MultiObjective::new(objective),
        }
    }
}

/// Online adaptive tuning handle for a hot parallel region (see module
/// docs): tune live, bypass when converged, warm re-tune on drift.
///
/// # Examples
///
/// Tuning a deterministic cost model in the application loop — after
/// convergence the calls become pass-throughs at the tuned point:
///
/// ```
/// use patsma::adaptive::TunedRegionConfig;
/// use patsma::workloads::synthetic::chunk_cost_model;
///
/// let mut region = TunedRegionConfig::new(1.0, 128.0).seed(7).build::<i32>();
/// while !region.is_converged() {
///     region.run_with_cost(|p| (chunk_cost_model(p[0] as f64, 48.0), ()));
/// }
/// let tuned = region.point()[0];
/// assert!((1..=128).contains(&tuned));
/// ```
pub struct TunedRegion<P: PointValue> {
    cfg: TunedRegionConfig,
    at: Autotuning,
    monitor: DriftMonitor,
    /// The parameter buffer handed to the application every iteration.
    point: Vec<P>,
    /// Completed re-tunes (generation 0 is the initial cold start).
    generation: u64,
    /// Evaluations consumed by earlier generations.
    evals_prior: u64,
    /// Total `run*` calls.
    iterations: u64,
    /// Whether the latest re-tune actually warm-started (false when the
    /// optimizer cannot export/consume a snapshot and restarted cold).
    last_retune_warm: bool,
    /// How the initial generation was seeded from the tuned table.
    seeded: TableSeed,
}

impl<P: PointValue> TunedRegion<P> {
    /// Run one application iteration, measuring its wall-clock as the cost
    /// (the paper's `singleExecRuntime` boundary). `target` receives the
    /// current parameters; its return value is passed through.
    pub fn run<R>(&mut self, target: impl FnOnce(&[P]) -> R) -> R {
        self.run_with_cost(|p| {
            let t0 = Instant::now();
            let out = target(p);
            (t0.elapsed().as_secs_f64(), out)
        })
    }

    /// Run one application iteration with an application-defined cost
    /// (energy, residual, items/sec inverted — anything to minimise):
    /// `target` returns `(cost, value)`.
    pub fn run_with_cost<R>(&mut self, target: impl FnOnce(&[P]) -> (f64, R)) -> R {
        self.iterations += 1;
        let bypass = self.at.is_finished();
        let mut measured = f64::NAN;
        let out = self.at.single_exec(&mut self.point, |p| {
            let (cost, value) = target(p);
            measured = cost;
            (cost, value)
        });
        // Only true bypass iterations feed the monitor: they ran the
        // converged point, so they are the baseline — and the signal.
        if bypass && self.monitor.observe(measured) {
            self.retune();
        } else if !bypass && self.at.is_finished() {
            // This call completed a tuning generation: remember the cell.
            self.store_converged();
        }
        out
    }

    /// Fold the just-converged result into the wired tuned table (no-op
    /// without one). The table's authority limit decides how much an
    /// existing cell moves.
    fn store_converged(&mut self) {
        let Some((table, key)) = &self.cfg.table else {
            return;
        };
        if let Some((point, cost)) = self.at.best() {
            table.observe(*key, &point, cost, None);
        }
    }

    /// Force a warm re-tune now (drift known out-of-band — e.g. the caller
    /// changed the problem size). Also the path the drift monitor triggers.
    pub fn retune(&mut self) {
        self.evals_prior += self.at.evaluations();
        self.generation += 1;
        let dim = self.cfg.dim();
        // Per-generation seed: deterministic, but a re-tune explores a
        // different trajectory than the generation it replaces.
        let seed = self.cfg.seed.wrapping_add(self.generation);
        let reduced = self.cfg.warm_budget();
        let mut opt = self
            .cfg
            .optimizer
            .build(dim, self.cfg.num_opt, reduced, seed);
        // A region pinned from a table exact hit has no search history to
        // export (zero evaluations); fabricate the snapshot from the cell
        // so a drift after a pin still re-tunes warm.
        let snapshot: Option<OptimizerState> = self
            .at
            .export_state()
            .or_else(|| self.table_snapshot(opt.name(), dim));
        self.last_retune_warm = snapshot
            .as_ref()
            .map(|s| opt.warm_start(s))
            .unwrap_or(false);
        if !self.last_retune_warm {
            // No snapshot to resume from: a reduced budget would just be a
            // worse cold start, so restart cold at the full budget.
            opt = self
                .cfg
                .optimizer
                .build(dim, self.cfg.num_opt, self.cfg.max_iter, seed);
        }
        let (lo, hi) = self.cfg.numeric_bounds();
        self.at = Autotuning::with_optimizer(lo, hi, self.cfg.ignore, opt);
        self.monitor.reset();
    }

    /// An [`OptimizerState`] fabricated from the wired table's exact-hit
    /// cell, for re-tuning a generation that never searched (pinned).
    fn table_snapshot(&self, optimizer: &str, dim: usize) -> Option<OptimizerState> {
        let (table, key) = self.cfg.table.as_ref()?;
        let cell = table.get(key).filter(|c| c.point.len() == dim)?;
        let (lo, hi) = self.cfg.numeric_bounds();
        let internal = encode_box(&cell.point, &lo, &hi);
        Some(OptimizerState {
            optimizer: optimizer.to_string(),
            best_internal: internal.clone(),
            best_cost: cell.cost,
            temperatures: None,
            points: vec![internal],
        })
    }

    /// True while the optimizer has converged and `run` bypasses straight
    /// to the tuned parameters (a drift signal flips this back to false).
    pub fn is_converged(&self) -> bool {
        self.at.is_finished()
    }

    /// The parameters as last handed to the application.
    pub fn point(&self) -> &[P] {
        &self.point
    }

    /// Number of tuned parameters.
    pub fn dim(&self) -> usize {
        self.cfg.dim()
    }

    /// Completed optimizer evaluations across all generations.
    pub fn evaluations(&self) -> u64 {
        self.evals_prior + self.at.evaluations()
    }

    /// Evaluations consumed by the current generation only (what a re-tune
    /// cost — compare against a cold start's `num_opt * max_iter`).
    pub fn generation_evaluations(&self) -> u64 {
        self.at.evaluations()
    }

    /// Completed re-tunes (0 until the first drift) — warm-started when
    /// the optimizer supplied a snapshot, cold restarts otherwise (see
    /// [`last_retune_was_warm`](Self::last_retune_was_warm)).
    pub fn retunes(&self) -> u64 {
        self.generation
    }

    /// Whether the latest re-tune warm-started from a snapshot (`false`
    /// before any re-tune, or when the optimizer restarted cold).
    pub fn last_retune_was_warm(&self) -> bool {
        self.last_retune_warm
    }

    /// How the initial generation was seeded from the wired tuned table
    /// ([`TableSeed::None`] without a table or on a miss).
    pub fn table_seed(&self) -> TableSeed {
        self.seeded
    }

    /// Total `run*` calls over the region's lifetime.
    pub fn iterations(&self) -> u64 {
        self.iterations
    }

    /// Best (user-domain point, cost) measured by the current generation.
    pub fn best(&self) -> Option<(Vec<f64>, f64)> {
        self.at.best()
    }

    /// Evaluation log of the current generation.
    pub fn history(&self) -> &[Sample] {
        self.at.history()
    }

    /// The drift monitor (inspect baseline/EWMA in reports).
    pub fn monitor(&self) -> &DriftMonitor {
        &self.monitor
    }

    /// The region's configuration.
    pub fn config(&self) -> &TunedRegionConfig {
        &self.cfg
    }
}

impl TunedRegion<i32> {
    /// Run one adaptively tuned iteration of `workload` — the generic
    /// integer-chunk adapter over any registry [`Workload`]: the region's
    /// point is the workload's parameter vector
    /// ([`Workload::run_iteration`]), the iteration's wall-clock is the
    /// cost, and the application value (residual, checksum) is returned.
    /// Build the region over the workload's own domain
    /// (`TunedRegionConfig::with_bounds(lo, hi)` from
    /// [`Workload::bounds`]); for typed/joint domains use
    /// [`TunedSpace::run_workload`] instead.
    pub fn run_workload(&mut self, workload: &mut dyn Workload) -> f64 {
        assert_eq!(
            self.dim(),
            workload.dim(),
            "region dimension must match the workload's parameter count"
        );
        self.run(|p| workload.run_iteration(p))
    }
}

/// Typed adaptive region over a mixed [`SearchSpace`] (built by
/// [`TunedRegionConfig::build_typed`]): the same converge → bypass → warm
/// re-tune lifecycle as [`TunedRegion`], but the application receives
/// decoded typed [`Point`]s — categorical kinds, exponent-quantized pow2
/// chunks, log-scaled floats. The optimizer underneath stages over the
/// unit hypercube and never sees the types (see [`crate::space`]).
///
/// The canonical use is joint `(schedule kind, chunk, steal-batch,
/// backoff)` loop tuning via
/// [`crate::sched::ParallelExec::auto_joint`].
///
/// # Examples
///
/// ```
/// use patsma::adaptive::TunedRegionConfig;
/// use patsma::sched::Schedule;
/// use patsma::workloads::synthetic::joint_cost_model;
///
/// let mut region = TunedRegionConfig::with_space(Schedule::joint_space(64))
///     .budget(3, 6)
///     .seed(9)
///     .build_typed();
/// while !region.is_converged() {
///     region.run_with_cost(|p| {
///         (joint_cost_model(p[0].index(), p[1].as_f64(), 24.0), ())
///     });
/// }
/// let tuned = Schedule::from_joint(region.point());
/// assert!(!tuned.label().is_empty());
/// ```
pub struct TunedSpace {
    /// The typed domain candidates decode through.
    space: SearchSpace,
    /// Numeric region staging the optimizer over the unit hypercube.
    inner: TunedRegion<f64>,
    /// Last decoded point handed to the application.
    point: Point,
    /// Scalarization + Pareto-front bookkeeping for vector-valued costs.
    mo: MultiObjective,
}

impl TunedSpace {
    /// Run one application iteration, measuring its wall-clock as the cost.
    /// `target` receives the current decoded point; its return value is
    /// passed through.
    pub fn run<R>(&mut self, target: impl FnOnce(&Point) -> R) -> R {
        self.run_with_cost(|p| {
            let t0 = Instant::now();
            let out = target(p);
            (t0.elapsed().as_secs_f64(), out)
        })
    }

    /// Run one adaptively tuned iteration of `workload` at the current
    /// decoded typed cell — the generic typed adapter over any registry
    /// [`Workload`] (it replaced the per-workload `multiply_joint` /
    /// `sweep_joint` entry points): the cell reaches the workload through
    /// [`Workload::run_point`], the iteration's wall-clock is the cost, and
    /// the application value is returned. Build the region over the
    /// workload's [`Workload::space`] or [`Workload::joint_space`]
    /// ([`TunedRegionConfig::for_workload`]).
    ///
    /// # Examples
    ///
    /// ```
    /// use patsma::adaptive::TunedRegionConfig;
    /// use patsma::workloads::{by_name_sized, SizeProfile};
    ///
    /// let mut w = by_name_sized("spmv", SizeProfile::Quick).unwrap();
    /// let mut region = TunedRegionConfig::for_workload(w.as_ref(), true)
    ///     .budget(2, 2)
    ///     .seed(7)
    ///     .build_typed();
    /// while !region.is_converged() {
    ///     region.run_workload(w.as_mut()); // one real multiply per call
    /// }
    /// assert!(w.joint_space().contains(region.point()));
    /// ```
    pub fn run_workload(&mut self, workload: &mut dyn Workload) -> f64 {
        let dim = self.dim();
        // Joint spaces replace the workload's first parameter (the chunk)
        // with the scheduler head: (kind, chunk, steal-batch, backoff).
        let joint_dim = workload.dim() - 1 + crate::sched::Schedule::JOINT_HEAD;
        assert!(
            dim == workload.dim() || dim == joint_dim,
            "space dim {dim} fits neither the plain ({}) nor the joint ({joint_dim}) surface of {}",
            workload.dim(),
            workload.name()
        );
        self.run(|p| workload.run_point(p))
    }

    /// Run one application iteration with an application-defined cost:
    /// `target` returns `(cost, value)`.
    pub fn run_with_cost<R>(&mut self, target: impl FnOnce(&Point) -> (f64, R)) -> R {
        let space = &self.space;
        let mut decoded: Option<Point> = None;
        let out = self.inner.run_with_cost(|u| {
            let p = space.decode_unit(u);
            let (cost, value) = target(&p);
            decoded = Some(p);
            (cost, value)
        });
        if let Some(p) = decoded {
            self.point = p;
        }
        out
    }

    /// Run one application iteration with a **vector-valued** cost:
    /// `target` returns `(CostVector, value)`. The vector is scalarized
    /// under the configured [`ObjectiveSpec`]
    /// ([`TunedRegionConfig::objective`]) before it reaches the optimizer,
    /// and every measured cell is offered to the region's [`ParetoFront`]
    /// ([`pareto`](Self::pareto)). Under the default scalar objective the
    /// scalarized cost of [`CostVector::from_scalar`] is the scalar itself,
    /// so this path is trajectory-identical to
    /// [`run_with_cost`](Self::run_with_cost).
    pub fn run_with_cost_vector<R>(
        &mut self,
        target: impl FnOnce(&Point) -> (CostVector, R),
    ) -> R {
        let space = &self.space;
        let mo = &mut self.mo;
        let mut decoded: Option<Point> = None;
        let out = self.inner.run_with_cost(|u| {
            let p = space.decode_unit(u);
            let (vector, value) = target(&p);
            let scalar = mo.observe(p.key(), Some(space.label(&p)), vector);
            decoded = Some(p);
            (scalar, value)
        });
        if let Some(p) = decoded {
            self.point = p;
        }
        out
    }

    /// The Pareto front accumulated by
    /// [`run_with_cost_vector`](Self::run_with_cost_vector) (empty until
    /// the first vector-valued measurement).
    pub fn pareto(&self) -> &ParetoFront {
        self.mo.front()
    }

    /// Force a warm re-tune now (drift known out-of-band).
    pub fn retune(&mut self) {
        self.inner.retune();
    }

    /// The typed point as last handed to the application.
    pub fn point(&self) -> &Point {
        &self.point
    }

    /// The typed point rendered through the space (categories by name).
    pub fn label(&self) -> String {
        self.space.label(&self.point)
    }

    /// The typed domain.
    pub fn space(&self) -> &SearchSpace {
        &self.space
    }

    /// Number of tuned dimensions.
    pub fn dim(&self) -> usize {
        self.space.dim()
    }

    /// True while converged and bypassing (see [`TunedRegion::is_converged`]).
    pub fn is_converged(&self) -> bool {
        self.inner.is_converged()
    }

    /// Completed optimizer evaluations across all generations.
    pub fn evaluations(&self) -> u64 {
        self.inner.evaluations()
    }

    /// Evaluations consumed by the current generation only.
    pub fn generation_evaluations(&self) -> u64 {
        self.inner.generation_evaluations()
    }

    /// Completed re-tunes (0 until the first drift).
    pub fn retunes(&self) -> u64 {
        self.inner.retunes()
    }

    /// Whether the latest re-tune warm-started from a snapshot.
    pub fn last_retune_was_warm(&self) -> bool {
        self.inner.last_retune_was_warm()
    }

    /// How the initial generation was seeded from the wired tuned table.
    /// Typed regions store **unit coordinates** in their cells — wire the
    /// same [`SearchSpace`] to make revisits recognisable.
    pub fn table_seed(&self) -> TableSeed {
        self.inner.table_seed()
    }

    /// Total `run*` calls over the region's lifetime.
    pub fn iterations(&self) -> u64 {
        self.inner.iterations()
    }

    /// Best (typed point, cost) measured by the current generation.
    pub fn best(&self) -> Option<(Point, f64)> {
        self.inner
            .best()
            .map(|(unit, cost)| (self.space.decode_unit(&unit), cost))
    }

    /// The drift monitor (inspect baseline/EWMA in reports).
    pub fn monitor(&self) -> &DriftMonitor {
        self.inner.monitor()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::synthetic::chunk_cost_model;

    fn converge(region: &mut TunedRegion<i32>, best: f64) {
        let mut guard = 0;
        while !region.is_converged() {
            region.run_with_cost(|p| (chunk_cost_model(p[0] as f64, best), ()));
            guard += 1;
            assert!(guard < 10_000, "tuning never converged");
        }
    }

    #[test]
    fn converges_then_bypasses_at_fixed_point() {
        let mut region = TunedRegionConfig::new(1.0, 128.0)
            .budget(4, 10)
            .seed(11)
            .build::<i32>();
        converge(&mut region, 48.0);
        let tuned = region.point()[0];
        // Bypass: the point stays frozen while costs stay stable.
        for _ in 0..50 {
            region.run_with_cost(|p| (chunk_cost_model(p[0] as f64, 48.0), ()));
            assert_eq!(region.point()[0], tuned);
        }
        assert_eq!(region.retunes(), 0);
        assert_eq!(region.evaluations(), 40); // 4 × 10
    }

    #[test]
    fn every_call_runs_the_target_exactly_once() {
        let mut region = TunedRegionConfig::new(1.0, 64.0)
            .budget(3, 4)
            .seed(3)
            .build::<i32>();
        let mut calls = 0u64;
        for _ in 0..100 {
            region.run_with_cost(|p| {
                calls += 1;
                (chunk_cost_model(p[0] as f64, 20.0), ())
            });
        }
        assert_eq!(calls, 100, "single-iteration protocol: no extra work");
        assert_eq!(region.iterations(), 100);
    }

    #[test]
    fn drift_triggers_warm_retune_and_recovers() {
        let mut region = TunedRegionConfig::new(1.0, 128.0)
            .budget(4, 10)
            .seed(5)
            .build::<i32>();
        converge(&mut region, 24.0);
        // Prime the drift baseline under the original landscape.
        for _ in 0..10 {
            region.run_with_cost(|p| (chunk_cost_model(p[0] as f64, 24.0), ()));
        }
        assert_eq!(region.retunes(), 0, "stable bypass must not re-tune");
        // The workload shifts: the optimum moves to 96 *and* every
        // iteration slows 2× (the problem grew, the machine got busier) —
        // the frozen point's cost leaves the band wherever tuning
        // converged.
        let shifted = |c: f64| 2.0 * chunk_cost_model(c, 96.0);
        let mut drift_seen_at = None;
        for i in 0..200 {
            region.run_with_cost(|p| (shifted(p[0] as f64), ()));
            if region.retunes() > 0 {
                drift_seen_at = Some(i);
                break;
            }
        }
        let detected = drift_seen_at.expect("drift never detected");
        assert!(detected < 50, "detection too slow: {detected} iterations");
        assert!(region.last_retune_was_warm());
        // Re-converge on the shifted landscape.
        let mut guard = 0;
        while !region.is_converged() {
            region.run_with_cost(|p| (shifted(p[0] as f64), ()));
            guard += 1;
            assert!(guard < 10_000);
        }
        // Warm re-tune budget: 50% of 10 iterations × 4 chains.
        assert_eq!(region.generation_evaluations(), 20);
        assert!(region.generation_evaluations() < 40, "must beat a cold start");
        // Recovered: the warm re-tune re-measures the persisted best first,
        // so on the new landscape the final point can never be *worse* than
        // the stale one.
        let stale = region.history().first().expect("re-measured stale best");
        let tuned_cost = shifted(region.point()[0] as f64);
        assert!(
            tuned_cost <= stale.cost + 1e-12,
            "retune regressed: {tuned_cost} vs stale {}",
            stale.cost
        );
    }

    #[test]
    fn manual_retune_without_snapshot_restarts_cold_at_full_budget() {
        // Grid search exports no state; a forced re-tune must fall back to
        // a cold start with the full budget.
        let mut region = TunedRegionConfig::new(1.0, 16.0)
            .optimizer(OptimizerSpec::Grid)
            .budget(1, 16)
            .build::<i32>();
        converge(&mut region, 6.0);
        let evals_before = region.evaluations();
        region.retune();
        assert!(!region.last_retune_was_warm());
        assert!(!region.is_converged());
        converge(&mut region, 6.0);
        assert_eq!(region.point()[0], 6, "exhaustive rescan finds the optimum");
        assert!(region.evaluations() > evals_before);
    }

    #[test]
    fn runtime_cost_variant_tunes_wall_clock() {
        let mut region = TunedRegionConfig::new(1.0, 8.0)
            .budget(2, 3)
            .seed(9)
            .build::<i32>();
        let mut guard = 0;
        while !region.is_converged() {
            region.run(|p| {
                // Busy-wait proportional to |p - 5|.
                let work = 50 * (1 + (p[0] - 5).unsigned_abs() as u64);
                let mut acc = 0u64;
                while acc < work {
                    acc += 1;
                    std::hint::black_box(acc);
                }
            });
            guard += 1;
            assert!(guard < 1000);
        }
        assert!(!region.history().is_empty());
        assert!((1..=8).contains(&region.point()[0]));
    }

    #[test]
    fn multi_parameter_region() {
        let mut region = TunedRegionConfig::with_bounds(vec![1.0, 1.0], vec![64.0, 64.0])
            .budget(5, 20)
            .seed(17)
            .build::<i32>();
        let mut guard = 0;
        while !region.is_converged() {
            region.run_with_cost(|p| {
                let c = chunk_cost_model(p[0] as f64, 12.0) + chunk_cost_model(p[1] as f64, 40.0);
                (c, ())
            });
            guard += 1;
            assert!(guard < 10_000);
        }
        assert_eq!(region.dim(), 2);
        assert_eq!(region.point().len(), 2);
    }

    #[test]
    #[should_panic(expected = "bounds length mismatch")]
    fn mismatched_bounds_panic() {
        let _ = TunedRegionConfig::with_bounds(vec![1.0], vec![2.0, 3.0]);
    }

    #[test]
    fn retune_budget_pct_builder_clamps_to_contract() {
        // Regression (ISSUE 9 satellite): the builder used to pass any
        // value through, silently granting warm re-tunes a *larger*
        // budget than a cold start.
        let cfg = TunedRegionConfig::new(1.0, 8.0).retune_budget_pct(400);
        assert_eq!(cfg.retune_budget_pct, 100);
        let cfg = TunedRegionConfig::new(1.0, 8.0).retune_budget_pct(0);
        assert_eq!(cfg.retune_budget_pct, 1);
        let cfg = TunedRegionConfig::new(1.0, 8.0).retune_budget_pct(75);
        assert_eq!(cfg.retune_budget_pct, 75, "in-range values untouched");
    }

    #[test]
    fn oversized_budget_poked_into_the_field_never_exceeds_cold() {
        // The config fields are public; a percentage written directly
        // into the struct is clamped again where the budget is computed.
        let mut cfg = TunedRegionConfig::new(1.0, 128.0).budget(4, 10).seed(11);
        cfg.retune_budget_pct = 400;
        let mut region = cfg.build::<i32>();
        converge(&mut region, 48.0);
        region.retune();
        assert!(region.last_retune_was_warm());
        converge(&mut region, 48.0);
        // Clamped to 100%: the warm generation gets exactly the cold
        // budget (4 × 10), never the 4 × 40 the raw field asks for.
        assert_eq!(region.generation_evaluations(), 40);
    }

    mod typed {
        use super::*;
        use crate::sched::Schedule;
        use crate::space::Value;
        use crate::workloads::synthetic::joint_cost_model;

        fn joint_cost(p: &crate::space::Point, best: f64) -> f64 {
            joint_cost_model(p[0].index(), p[1].as_f64(), best)
        }

        fn converge_joint(region: &mut TunedSpace, best: f64) {
            let mut guard = 0;
            while !region.is_converged() {
                region.run_with_cost(|p| (joint_cost(p, best), ()));
                guard += 1;
                assert!(guard < 10_000, "typed tuning never converged");
            }
        }

        #[test]
        fn typed_region_converges_and_bypasses_on_a_fixed_cell() {
            let mut region = TunedRegionConfig::with_space(Schedule::joint_space(128))
                .budget(4, 10)
                .seed(11)
                .build_typed();
            converge_joint(&mut region, 48.0);
            assert_eq!(region.evaluations(), 40);
            let frozen = region.point().clone();
            assert!(region.space().contains(&frozen));
            assert!(matches!(frozen[0], Value::Cat(_)));
            for _ in 0..30 {
                region.run_with_cost(|p| (joint_cost(p, 48.0), ()));
                assert_eq!(region.point(), &frozen, "bypass must freeze the cell");
            }
            assert_eq!(region.retunes(), 0);
            // The label decodes through the space (kind by name).
            let label = region.label();
            assert!(
                Schedule::KINDS.iter().any(|k| label.starts_with(k)),
                "label {label:?}"
            );
        }

        #[test]
        fn typed_region_detects_drift_and_warm_retunes() {
            let mut region = TunedRegionConfig::with_space(Schedule::joint_space(128))
                .budget(4, 10)
                .seed(5)
                .build_typed();
            converge_joint(&mut region, 24.0);
            for _ in 0..10 {
                region.run_with_cost(|p| (joint_cost(p, 24.0), ()));
            }
            assert_eq!(region.retunes(), 0, "stable bypass must not re-tune");
            // The landscape shifts and slows; the frozen cell leaves the band.
            let shifted = |p: &crate::space::Point| 2.0 * joint_cost(p, 96.0);
            let mut detected = false;
            for _ in 0..200 {
                region.run_with_cost(|p| (shifted(p), ()));
                if region.retunes() > 0 {
                    detected = true;
                    break;
                }
            }
            assert!(detected, "drift never detected");
            assert!(region.last_retune_was_warm());
            let mut guard = 0;
            while !region.is_converged() {
                region.run_with_cost(|p| (shifted(p), ()));
                guard += 1;
                assert!(guard < 10_000);
            }
            // Warm budget: 50% of 10 iterations × 4 chains.
            assert_eq!(region.generation_evaluations(), 20);
        }

        #[test]
        fn every_typed_call_runs_the_target_exactly_once() {
            let mut region = TunedRegionConfig::with_space(Schedule::joint_space(64))
                .budget(2, 4)
                .seed(3)
                .build_typed();
            let mut calls = 0u64;
            for _ in 0..50 {
                region.run_with_cost(|p| {
                    calls += 1;
                    (joint_cost(p, 16.0), ())
                });
            }
            assert_eq!(calls, 50, "single-iteration protocol");
            assert_eq!(region.iterations(), 50);
            assert_eq!(region.dim(), Schedule::JOINT_HEAD);
        }

        #[test]
        #[should_panic(expected = "pow2/log/categorical")]
        fn numeric_build_rejects_mixed_spaces() {
            let _ = TunedRegionConfig::with_space(Schedule::joint_space(8)).build::<i32>();
        }

        #[test]
        fn vector_cost_under_default_objective_matches_the_scalar_path() {
            // scalarize(from_scalar(c)) == c exactly under the scalar
            // preset (1·median + 0·p95 + 0·inv_eff), so the vector path
            // must walk the identical same-seed trajectory.
            let cfg = || {
                TunedRegionConfig::with_space(Schedule::joint_space(128))
                    .budget(4, 10)
                    .seed(11)
            };
            let mut scalar = cfg().build_typed();
            converge_joint(&mut scalar, 48.0);
            let mut vector = cfg().build_typed();
            let mut guard = 0;
            while !vector.is_converged() {
                vector.run_with_cost_vector(|p| {
                    (CostVector::from_scalar(joint_cost(p, 48.0)), ())
                });
                guard += 1;
                assert!(guard < 10_000, "vector tuning never converged");
            }
            assert_eq!(vector.point(), scalar.point(), "trajectories diverged");
            assert_eq!(vector.evaluations(), scalar.evaluations());
            let front = vector.pareto();
            assert!(!front.is_empty() && front.len() <= front.cap());
            let winner = front.winner().expect("non-empty front has a winner");
            let best = vector.best().expect("converged region has a best").1;
            assert!((winner.scalar - best).abs() < 1e-12);
            // The scalar path never measured a vector: its front stays empty.
            assert!(scalar.pareto().is_empty());
        }

        #[test]
        fn vector_cost_scalarizes_under_fastest_stable() {
            let spec = ObjectiveSpec::parse("fastest-stable").expect("known preset");
            let mut region =
                TunedRegionConfig::with_space(SearchSpace::new(vec![Dim::Int { lo: 1, hi: 64 }]))
                    .budget(2, 6)
                    .seed(7)
                    .objective(spec)
                    .build_typed();
            let mut guard = 0;
            while !region.is_converged() {
                region.run_with_cost_vector(|p| {
                    let x = p[0].as_f64();
                    // Constant median, p95 rising with the knob: only the
                    // p95 term differentiates candidates.
                    let c = CostVector::new(1.0, 1.0 + x / 8.0, 1.0, 1).expect("finite");
                    (c, ())
                });
                guard += 1;
                assert!(guard < 10_000);
            }
            let winner = region.pareto().winner().expect("front populated");
            // fastest-stable weights (1, 2, 0): scalar = median + 2·p95.
            let p95 = 1.0 + winner.key[0] / 8.0;
            assert!((winner.scalar - (1.0 + 2.0 * p95)).abs() < 1e-12);
        }
    }
}
