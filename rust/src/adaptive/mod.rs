//! The online adaptive tuning runtime — auto-tuning embedded **inside** the
//! application's hot loop.
//!
//! The paper's headline promise (§1, Fig. 1 "Single Iteration" mode) is
//! *real-time* optimization: the tuner rides along with the application,
//! spends its evaluation budget on real iterations, then gets out of the
//! way. The [`crate::service`] module industrialised the *offline* side of
//! that story (concurrent sessions, persisted state, warm re-tuning between
//! processes); this module is the *online* side — a handle an application
//! embeds directly:
//!
//! * [`TunedRegion`] wraps one hot parallel region. Each
//!   [`run`](TunedRegion::run) call executes exactly one application
//!   iteration: during tuning the iteration doubles as a candidate
//!   evaluation (the Single-Iteration protocol), after convergence the
//!   calls bypass straight to the tuned parameters at zero optimizer
//!   overhead.
//! * [`DriftMonitor`] watches the bypass costs (EWMA against a baseline
//!   band built on [`crate::stats::Welford`]) and detects workload drift —
//!   the moment the frozen parameters stopped being the right ones.
//! * On drift the region **warm re-tunes**: it snapshots the optimizer
//!   ([`crate::optimizer::OptimizerState`]), rebuilds it at a reduced
//!   budget and resumes from the snapshot with
//!   [`crate::optimizer::ResetLevel::Soft`] semantics — re-converging with
//!   strictly fewer evaluations than a cold restart (pinned by
//!   `rust/tests/adaptive.rs`).
//!
//! The substrate hook is [`crate::sched::ParallelExec::auto`]
//! (`pool.exec(a, b).auto(&mut region).run(body)`): an auto-chunked loop
//! whose `Dynamic(chunk)` granularity is chosen live by a `TunedRegion` —
//! the paper's tuned OpenMP clause as a drop-in loop primitive. Its joint
//! sibling [`crate::sched::ParallelExec::auto_joint`] hands a
//! [`TunedSpace`] the whole `(kind, chunk, steal-batch, backoff)` head —
//! the typed [`crate::space::SearchSpace`] machinery tunes the
//! categorical policy *together with* its granularity and the
//! work-stealing executor's own knobs. `patsma adaptive demo` shows the
//! full converge → drift → recover cycle on the CLI.
//!
//! Registry workloads need no wiring at all: the generic adapters
//! [`TunedRegion::run_workload`] (integer parameter vector) and
//! [`TunedSpace::run_workload`] (typed / joint cells via
//! [`crate::workloads::Workload::run_point`]) tune any
//! [`crate::workloads::NAMES`] entry online — `patsma adaptive run
//! --workload spmv --joint` on the CLI.
//!
//! # Examples
//!
//! Tune a chunk parameter online, then keep running at zero overhead:
//!
//! ```
//! use patsma::adaptive::TunedRegionConfig;
//! use patsma::workloads::synthetic::chunk_cost_model;
//!
//! let mut region = TunedRegionConfig::new(1.0, 128.0)
//!     .budget(4, 8)
//!     .seed(42)
//!     .build::<i32>();
//!
//! // The application loop: `run_with_cost` hands back the current chunk
//! // and consumes this iteration's cost. Tuning finishes inside the loop.
//! for _ in 0..64 {
//!     region.run_with_cost(|p| (chunk_cost_model(p[0] as f64, 48.0), ()));
//! }
//! assert!(region.is_converged());
//! assert_eq!(region.evaluations(), 32); // 4 chains × 8 iterations
//! ```

pub mod drift;
pub mod region;
pub mod table;

pub use drift::{DriftConfig, DriftMonitor};
pub use region::{TunedRegion, TunedRegionConfig, TunedSpace};
pub use table::{
    ContextKey, SharedTunedTable, TableAuthority, TableEntry, TableHit, TableSeed, TableUpdate,
    TunedCell, TunedTable,
};
