//! `cargo bench --bench rtm` — regenerates experiment(s): e9
//! (see DESIGN.md §4 for the paper artifact each id reproduces).
//! Set PATSMA_QUICK=1 for the fast CI variant.

fn main() {
    let quick = std::env::var("PATSMA_QUICK").is_ok();
    for id in ["e9"] {
        match patsma::coordinator::run(id, quick) {
            Ok(out) => println!("{out}"),
            Err(e) => {
                eprintln!("{id} failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
