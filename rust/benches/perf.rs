//! `cargo bench --bench perf` — the §Perf microbenchmarks (EXPERIMENTS.md):
//!
//! 1. `parallel_for` dispatch latency (empty body) — the floor below which
//!    chunk effects cannot be measured;
//! 2. tuner `single_exec_runtime` overhead vs calling the target directly —
//!    the paper's "minimal execution overhead" claim, quantified;
//! 3. per-schedule scheduling overhead at fine granularity (counter
//!    contention) on a real loop body.

use patsma::bench::{bench, fmt_time, render_table};
use patsma::sched::{Schedule, ThreadPool};
use patsma::tuner::Autotuning;
use patsma::workloads::rb_gauss_seidel::RbGaussSeidel;
use std::hint::black_box;

fn main() {
    let quick = std::env::var("PATSMA_QUICK").is_ok();
    let samples = if quick { 200 } else { 2000 };
    let pool = ThreadPool::global();
    println!("# §Perf microbenchmarks ({} threads)\n", pool.threads());

    // --- 1. dispatch latency ---
    let mut rows = Vec::new();
    for t in [1usize, 2, pool.threads().min(8), pool.threads()] {
        let p = ThreadPool::new(t);
        rows.push(bench(&format!("empty region, {t} threads"), 50, samples, || {
            p.exec(0, t).sched(Schedule::Static).run(|r| {
                black_box(r.len());
            });
        }));
    }
    println!(
        "{}",
        render_table("1. fork/join dispatch latency (empty body)", &rows, None)
    );

    // --- 2. tuner overhead on the hot path ---
    let n = 256;
    let mut w_direct = RbGaussSeidel::new(n, pool);
    let direct = bench("direct sweep(32)", 10, if quick { 50 } else { 300 }, || {
        let _ = w_direct.sweep(32);
    });
    let mut w_tuned = RbGaussSeidel::new(n, pool);
    // A tuner that converged long ago: measures the pure bypass overhead.
    let mut at = Autotuning::with_seed(32.0, 32.0, 0, 1, 1, 1, 1);
    let mut chunk = [32i32; 1];
    while !at.is_finished() {
        at.single_exec_runtime(&mut chunk, |p| w_tuned.sweep(p[0] as usize));
    }
    let bypass = bench(
        "single_exec_runtime after convergence",
        10,
        if quick { 50 } else { 300 },
        || {
            let _ = at.single_exec_runtime(&mut chunk, |p| w_tuned.sweep(p[0] as usize));
        },
    );
    let overhead = (bypass.median() - direct.median()).max(0.0);
    println!(
        "{}",
        render_table(
            "2. tuner bypass overhead (RB-GS n=256, chunk=32)",
            &[direct.clone(), bypass.clone()],
            Some(0)
        )
    );
    println!(
        "bypass overhead ≈ {} per iteration ({:.3}% of the sweep)\n",
        fmt_time(overhead),
        100.0 * overhead / direct.median()
    );

    // --- 3. scheduling overhead vs granularity on a real body ---
    let mut rows = Vec::new();
    let work = 4096usize;
    for (label, sched) in [
        ("dynamic,1", Schedule::Dynamic(1)),
        ("dynamic,8", Schedule::Dynamic(8)),
        ("dynamic,64", Schedule::Dynamic(64)),
        ("guided,1", Schedule::Guided(1)),
        ("static", Schedule::Static),
    ] {
        rows.push(bench(label, 20, if quick { 100 } else { 500 }, || {
            pool.exec(0, work).sched(sched).run(|r| {
                let mut acc = 0u64;
                for i in r {
                    acc = acc.wrapping_add((i as u64).wrapping_mul(0x9E37_79B9));
                }
                black_box(acc);
            });
        }));
    }
    println!(
        "{}",
        render_table(
            &format!("3. schedule overhead, {work} trivial iterations"),
            &rows,
            Some(4)
        )
    );
}
