//! `cargo bench --bench fdm3d` — regenerates experiment(s): e8
//! (see DESIGN.md §4 for the paper artifact each id reproduces).
//! Set PATSMA_QUICK=1 for the fast CI variant.

fn main() {
    let quick = std::env::var("PATSMA_QUICK").is_ok();
    for id in ["e8"] {
        match patsma::coordinator::run(id, quick) {
            Ok(out) => println!("{out}"),
            Err(e) => {
                eprintln!("{id} failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
