"""L2 correctness: model-level semantics (sweep convergence, leapfrog
stability, state plumbing) and lowering shape checks."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def test_rb_sweep_reduces_residual():
    padded = model.initial_rb_grid(32)
    residuals = []
    for _ in range(20):
        padded, diff = model.rb_sweep(padded, 16, 16)
        residuals.append(float(diff))
    assert residuals[-1] < residuals[0] * 0.9
    assert all(np.isfinite(residuals))


def test_rb_sweep_is_variant_independent():
    """The tuned parameter must not change the numerics - the invariant
    behind the whole paper, at the XLA layer."""
    p1 = model.initial_rb_grid(64)
    p2 = model.initial_rb_grid(64)
    for _ in range(3):
        p1, d1 = model.rb_sweep(p1, 8, 8)
        p2, d2 = model.rb_sweep(p2, 32, 64)
        np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
        assert float(d1) == float(d2)


def test_rb_sweep_boundary_untouched():
    padded = model.initial_rb_grid(16)
    boundary_before = np.asarray(padded).copy()
    padded, _ = model.rb_sweep(padded, 8, 8)
    after = np.asarray(padded)
    np.testing.assert_array_equal(after[0, :], boundary_before[0, :])
    np.testing.assert_array_equal(after[-1, :], boundary_before[-1, :])
    np.testing.assert_array_equal(after[:, 0], boundary_before[:, 0])
    np.testing.assert_array_equal(after[:, -1], boundary_before[:, -1])


def test_initial_grid_matches_rust_structure():
    g = np.asarray(model.initial_rb_grid(8))
    side = 10
    assert g.shape == (side, side)
    assert g[0, 1] == 100.0  # top edge hot
    assert g[side - 1, 1] == 0.0  # bottom cold
    assert abs(g[0, side - 1] - 50.0) < 1e-12  # right ramp at top
    assert np.all(g[1:-1, 1:-1] == 0.0)  # interior zero


def test_wave_step_state_plumbing():
    n = 16
    rng = np.random.default_rng(11)
    curr = jnp.asarray(rng.uniform(-1, 1, (n + 4, n + 4)), dtype=jnp.float32)
    prev = jnp.asarray(rng.uniform(-1, 1, (n, n)), dtype=jnp.float32)
    vf = jnp.full((n, n), 0.04, dtype=jnp.float32)
    nxt_padded, nxt_prev, energy = model.wave_step(curr, prev, vf, 8, 8)
    # prev' is the old interior.
    np.testing.assert_array_equal(
        np.asarray(nxt_prev), np.asarray(curr)[2:-2, 2:-2]
    )
    # interior of next_padded equals the reference update.
    expected = ref.wave_step_ref(curr, prev, vf)
    np.testing.assert_allclose(
        np.asarray(nxt_padded)[2:-2, 2:-2], expected, rtol=1e-6, atol=1e-6
    )
    assert float(energy) >= 0.0


def test_wave_energy_bounded_over_steps():
    """Leapfrog with small Courant factor on a zero-boundary box: energy of
    a random initial field must stay bounded over many steps."""
    n = 32
    rng = np.random.default_rng(5)
    interior = rng.uniform(-1, 1, (n, n)).astype(np.float32) * 0.01
    curr = jnp.zeros((n + 4, n + 4), dtype=jnp.float32).at[2:-2, 2:-2].set(interior)
    prev = jnp.asarray(interior)
    vf = jnp.full((n, n), 0.05, dtype=jnp.float32)
    peak = 0.0
    for _ in range(100):
        curr, prev, e = model.wave_step(curr, prev, vf, 16, 16)
        peak = max(peak, float(e))
        assert np.isfinite(float(e))
    assert float(e) < peak * 10.0, "leapfrog unstable"
