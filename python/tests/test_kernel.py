"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE build-time
signal) plus hypothesis sweeps over shapes, block sizes and data."""

import hypothesis
import hypothesis.strategies as st
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref, stencil, wave

RNG = np.random.default_rng(1234)


def random_padded(n: int, dtype=jnp.float64, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=(n + 2, n + 2)), dtype=dtype)


# ---------------------------------------------------------------------------
# Red-black stencil kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 16), (32, 32), (16, 32), (32, 16)])
@pytest.mark.parametrize("colour", [0, 1])
def test_rb_colour_matches_ref(bm, bn, colour):
    n = 32
    p = random_padded(n, seed=42)
    out_kernel = stencil.rb_colour_step(p, colour, bm, bn)
    out_ref = ref.rb_colour_step_ref(p, colour)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=0, atol=0)


def test_rb_colour_preserves_other_colour():
    n = 16
    p = random_padded(n, seed=7)
    out = stencil.rb_colour_step(p, 0, 8, 8)
    centre = np.asarray(p)[1:-1, 1:-1]
    rows = np.arange(1, n + 1)[:, None]
    cols = np.arange(1, n + 1)[None, :]
    other = ((rows + cols) % 2) == 1
    np.testing.assert_array_equal(np.asarray(out)[other], centre[other])


def test_rb_full_sweep_matches_numpy_loop_oracle():
    """The tiled two-phase sweep equals the in-place loop-level Gauss-Seidel
    (proving the colour decomposition preserves GS semantics)."""
    from compile import model

    n = 16
    p = random_padded(n, seed=3)
    new_padded, diff = model.rb_sweep(p, 8, 8)
    g_np, diff_np = ref.rb_sweep_numpy(np.asarray(p))
    np.testing.assert_allclose(np.asarray(new_padded), g_np, rtol=1e-12, atol=1e-12)
    assert abs(float(diff) - diff_np) < 1e-9 * max(diff_np, 1.0)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    bshape=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    colour=st.sampled_from([0, 1]),
)
def test_rb_colour_hypothesis_shapes(n_blocks, bshape, seed, colour):
    """Property: kernel == oracle for every (grid, block, data, colour)."""
    n = n_blocks * bshape
    p = random_padded(n, seed=seed)
    out_kernel = stencil.rb_colour_step(p, colour, bshape, bshape)
    out_ref = ref.rb_colour_step_ref(p, colour)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=0, atol=0)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_rb_colour_f32_dtype(seed):
    n = 16
    p = random_padded(n, dtype=jnp.float32, seed=seed)
    out_kernel = stencil.rb_colour_step(p, 0, 8, 8)
    out_ref = ref.rb_colour_step_ref(p, 0)
    assert out_kernel.dtype == jnp.float32
    np.testing.assert_allclose(out_kernel, out_ref, rtol=1e-6, atol=1e-6)


def test_rb_rejects_nondividing_blocks():
    p = random_padded(30)
    with pytest.raises(AssertionError):
        stencil.rb_colour_step(p, 0, 8, 8)


def test_rb_variants_all_divide_default_n():
    for bm, bn in stencil.RB_VARIANTS:
        assert 256 % bm == 0 and 256 % bn == 0


def test_vmem_estimate_monotone():
    sizes = [stencil.vmem_bytes(b, b) for b in (8, 16, 32, 64)]
    assert sizes == sorted(sizes)
    assert stencil.vmem_bytes(8, 8) == 4 * (10 * 10 + 64)


# ---------------------------------------------------------------------------
# Wave kernel
# ---------------------------------------------------------------------------


def wave_inputs(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    curr = jnp.asarray(
        rng.uniform(-1.0, 1.0, size=(n + 4, n + 4)), dtype=jnp.float32
    )
    prev = jnp.asarray(rng.uniform(-1.0, 1.0, size=(n, n)), dtype=jnp.float32)
    vf = jnp.asarray(rng.uniform(0.0, 0.1, size=(n, n)), dtype=jnp.float32)
    return curr, prev, vf


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 16), (8, 32), (32, 8), (32, 32)])
def test_wave_matches_ref(bm, bn):
    n = 32
    curr, prev, vf = wave_inputs(n, seed=5)
    out_kernel = wave.wave_step_tiles(curr, prev, vf, bm, bn)
    out_ref = ref.wave_step_ref(curr, prev, vf)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=3),
    bshape=st.sampled_from([8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_wave_hypothesis_shapes(n_blocks, bshape, seed):
    n = n_blocks * bshape
    curr, prev, vf = wave_inputs(n, seed=seed)
    out_kernel = wave.wave_step_tiles(curr, prev, vf, bshape, bshape)
    out_ref = ref.wave_step_ref(curr, prev, vf)
    np.testing.assert_allclose(out_kernel, out_ref, rtol=1e-6, atol=1e-6)


def test_wave_zero_field_stays_zero():
    n = 16
    curr = jnp.zeros((n + 4, n + 4), dtype=jnp.float32)
    prev = jnp.zeros((n, n), dtype=jnp.float32)
    vf = jnp.full((n, n), 0.05, dtype=jnp.float32)
    out = wave.wave_step_tiles(curr, prev, vf, 8, 8)
    assert float(jnp.abs(out).max()) == 0.0


def test_wave_variants_divide_default_n():
    for bm, bn in wave.WAVE_VARIANTS:
        assert 128 % bm == 0 and 128 % bn == 0
