"""AOT pipeline checks: HLO text artifacts exist, parse-ably shaped, and
the manifest is consistent. (The Rust integration test re-executes the
artifacts through PJRT and compares numerics - see rust/tests/.)"""

import os
import tempfile

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot
from compile.kernels import stencil, wave


def test_lower_rb_produces_hlo_text():
    text = aot.lower_rb(32, 32)
    assert "HloModule" in text
    assert "f64[258,258]" in text  # padded input shape for n = 256
    assert "ROOT" in text


def test_lower_wave_produces_hlo_text():
    text = aot.lower_wave(16, 16)
    assert "HloModule" in text
    assert "f32[132,132]" in text  # padded input for n = 128


def test_build_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as d:
        # Restrict variants for test speed.
        old_rb, old_wave = stencil.RB_VARIANTS[:], wave.WAVE_VARIANTS[:]
        stencil.RB_VARIANTS[:] = [(32, 32)]
        wave.WAVE_VARIANTS[:] = [(32, 32)]
        try:
            manifest = aot.build(d)
        finally:
            stencil.RB_VARIANTS[:] = old_rb
            wave.WAVE_VARIANTS[:] = old_wave
        assert len(manifest) == 2
        lines = open(os.path.join(d, "manifest.txt")).read().strip().splitlines()
        assert len(lines) == 2
        for line in lines:
            kind, name, path, n, bm, bn, vmem = line.split()
            assert kind in ("rb_sweep", "wave")
            assert os.path.exists(os.path.join(d, path))
            assert int(n) % int(bm) == 0 and int(n) % int(bn) == 0
            assert int(vmem) > 0
