"""L2: the JAX compute graphs the Rust runtime executes, calling the L1
Pallas kernels.

Two models, mirroring the Rust substrate workloads so the runtime-tuning
experiments can cross-check numerics between layers:

* ``rb_sweep`` - one full red-black Gauss-Seidel sweep (float64, matching
  ``rust/src/workloads/rb_gauss_seidel.rs``): padded grid in, padded grid +
  residual out. One executable per (bm, bn) kernel variant.
* ``wave_step`` - one 2-D leapfrog FDM step (float32): state in, state +
  field energy out. One executable per variant.

The functions are shape-specialised at lowering time (aot.py): XLA/PJRT
executables are static-shape, so each (n, bm, bn) combination is its own
artifact - exactly the "pre-compiled variant" model the auto-tuner selects
among at runtime.
"""

import jax.numpy as jnp

from .kernels.stencil import rb_colour_step
from .kernels.wave import wave_step_tiles


def rb_sweep(padded, bm: int, bn: int):
    """One full red-black sweep: colour 0 then colour 1.

    Returns ``(new_padded, residual)`` with ``residual = sum |delta|`` over
    the interior - the same quantity the Rust substrate reports.
    """
    before = padded[1:-1, 1:-1]
    interior = rb_colour_step(padded, 0, bm, bn)
    padded = padded.at[1:-1, 1:-1].set(interior)
    interior = rb_colour_step(padded, 1, bm, bn)
    padded = padded.at[1:-1, 1:-1].set(interior)
    diff = jnp.sum(jnp.abs(padded[1:-1, 1:-1] - before))
    return padded, diff


def wave_step(curr_padded, prev, vfact, bm: int, bn: int):
    """One leapfrog step of the 2-D acoustic model.

    State convention (halo 2, Dirichlet ring kept at zero):
      * ``curr_padded``: (n+4, n+4) current field;
      * ``prev``: (n, n) previous interior;
      * ``vfact``: (n, n) squared Courant factor.

    Returns ``(next_padded, next_prev, energy)`` so the caller feeds the
    outputs straight back in - the Rust runtime's time-stepping loop.
    """
    nxt = wave_step_tiles(curr_padded, prev, vfact, bm, bn)
    next_prev = curr_padded[2:-2, 2:-2]
    next_padded = curr_padded.at[2:-2, 2:-2].set(nxt)
    energy = jnp.sum(jnp.square(nxt))
    return next_padded, next_prev, energy


def initial_rb_grid(n: int):
    """The same asymmetric Laplace boundary problem the Rust substrate
    builds (rb_gauss_seidel.rs init_grid), padded (n+2, n+2) float64."""
    side = n + 2
    g = jnp.zeros((side, side), dtype=jnp.float64)
    g = g.at[0, :].set(100.0)
    frac = jnp.arange(side, dtype=jnp.float64) / (side - 1)
    g = g.at[:, 0].set(100.0 * (1.0 - frac))
    g = g.at[:, side - 1].set(50.0 * (1.0 - frac))
    # Corners follow the row-0 / row-last rule like the Rust code (top edge
    # written first, then side ramps overwrite their columns).
    g = g.at[side - 1, :].set(0.0)
    g = g.at[side - 1, 0].set(0.0)
    g = g.at[0, 0].set(100.0)
    g = g.at[0, side - 1].set(50.0)
    return g
