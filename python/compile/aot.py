"""AOT pipeline: lower the L2 models (with their L1 Pallas kernels) to HLO
text artifacts the Rust runtime loads via PJRT.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``artifacts/``:

* ``rb_sweep_bm{bm}_bn{bn}.hlo.txt``  - one per stencil variant (n = 256,
  float64): ``(padded) -> (padded', residual)``;
* ``wave_bm{bm}_bn{bn}.hlo.txt``      - one per wave variant (n = 128,
  float32): ``(curr_padded, prev, vfact) -> (curr', prev', energy)``;
* ``manifest.txt`` - one line per artifact:
  ``kind name file n bm bn vmem_bytes``.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from ``python/``).
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import stencil, wave  # noqa: E402

# Problem sizes baked into the artifacts (XLA executables are static-shape).
RB_N = 256
WAVE_N = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_rb(bm: int, bn: int) -> str:
    spec = jax.ShapeDtypeStruct((RB_N + 2, RB_N + 2), jnp.float64)

    def fn(padded):
        return model.rb_sweep(padded, bm, bn)

    return to_hlo_text(jax.jit(fn).lower(spec))


def lower_wave(bm: int, bn: int) -> str:
    cp = jax.ShapeDtypeStruct((WAVE_N + 4, WAVE_N + 4), jnp.float32)
    inner = jax.ShapeDtypeStruct((WAVE_N, WAVE_N), jnp.float32)

    def fn(curr_padded, prev, vfact):
        return model.wave_step(curr_padded, prev, vfact, bm, bn)

    return to_hlo_text(jax.jit(fn).lower(cp, inner, inner))


def build(out_dir: str) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for bm, bn in stencil.RB_VARIANTS:
        if RB_N % bm or RB_N % bn:
            continue
        name = f"rb_sweep_bm{bm}_bn{bn}"
        path = f"{name}.hlo.txt"
        text = lower_rb(bm, bn)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest.append(
            f"rb_sweep {name} {path} {RB_N} {bm} {bn} "
            f"{stencil.vmem_bytes(bm, bn, dtype_bytes=8)}"
        )
        print(f"  {name}: {len(text)} chars")
    for bm, bn in wave.WAVE_VARIANTS:
        if WAVE_N % bm or WAVE_N % bn:
            continue
        name = f"wave_bm{bm}_bn{bn}"
        path = f"{name}.hlo.txt"
        text = lower_wave(bm, bn)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        manifest.append(
            f"wave {name} {path} {WAVE_N} {bm} {bn} {wave.vmem_bytes(bm, bn)}"
        )
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    manifest = build(args.out_dir)
    print(f"wrote {len(manifest)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
