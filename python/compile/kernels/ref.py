"""Pure-jnp oracles for the Pallas kernels - the build-time correctness
signal. Everything here is deliberately written with whole-array ops (no
tiling, no pallas) so a disagreement always indicts the kernel."""

import jax.numpy as jnp
import numpy as np


def rb_colour_step_ref(padded, colour: int):
    """Reference for stencil.rb_colour_step: one colour phase on the padded
    grid; returns the (n, n) interior."""
    win = padded
    centre = win[1:-1, 1:-1]
    new = 0.25 * (win[:-2, 1:-1] + win[2:, 1:-1] + win[1:-1, :-2] + win[1:-1, 2:])
    n = centre.shape[0]
    rows = jnp.arange(1, n + 1)[:, None]
    cols = jnp.arange(1, n + 1)[None, :]
    mask = ((rows + cols) % 2) == colour
    return jnp.where(mask, new, centre)


def rb_sweep_ref(padded):
    """Full red-black sweep (colour 0 then colour 1), matching the Rust
    substrate's ordering; returns (new_padded, residual)."""
    before = padded[1:-1, 1:-1]
    interior = rb_colour_step_ref(padded, 0)
    padded = padded.at[1:-1, 1:-1].set(interior)
    interior = rb_colour_step_ref(padded, 1)
    padded = padded.at[1:-1, 1:-1].set(interior)
    diff = jnp.sum(jnp.abs(padded[1:-1, 1:-1] - before))
    return padded, diff


def rb_sweep_numpy(padded_np: np.ndarray):
    """Loop-level numpy oracle (matches rust/src/workloads/rb_gauss_seidel.rs
    cell by cell): in-place Gauss-Seidel within the sweep."""
    g = padded_np.astype(np.float64).copy()
    side = g.shape[0]
    n = side - 2
    diff = 0.0
    for colour in (0, 1):
        for i in range(1, n + 1):
            j0 = 1 + ((i + 1 + colour) % 2)
            for j in range(j0, n + 1, 2):
                old = g[i, j]
                new = 0.25 * (g[i, j - 1] + g[i, j + 1] + g[i - 1, j] + g[i + 1, j])
                g[i, j] = new
                diff += abs(new - old)
    return g, diff


def wave_step_ref(curr_padded, prev, vfact):
    """Reference for wave.wave_step_tiles (4th-order Laplacian leapfrog)."""
    w0, w1, w2 = -5.0 / 2.0, 4.0 / 3.0, -1.0 / 12.0
    win = curr_padded
    c = win[2:-2, 2:-2]
    lap = (
        2.0 * w0 * c
        + w1 * (win[1:-3, 2:-2] + win[3:-1, 2:-2] + win[2:-2, 1:-3] + win[2:-2, 3:-1])
        + w2 * (win[:-4, 2:-2] + win[4:, 2:-2] + win[2:-2, :-4] + win[2:-2, 4:])
    )
    return 2.0 * c - prev + vfact * lap
