"""L1 Pallas kernel: tiled 2-D acoustic leapfrog update (the FDM hot-spot).

A 2-D slice of the 3-D FDM propagator the paper's validation studies tune
(refs [10, 11]): 4th-order Laplacian in space, 2nd-order leapfrog in time,

    nxt[i,j] = 2 c[i,j] - prv[i,j] + vf[i,j] * lap4(c)[i,j]

where ``c`` arrives padded with a halo of 2 and ``prv``/``vf``/``nxt`` are
interior-sized. Tiling mirrors stencil.py: the ``(bm, bn)`` output tile
stages a ``(bm+4, bn+4)`` input window - the knob the auto-tuner turns.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 4th-order centred second-derivative coefficients.
W0 = -5.0 / 2.0
W1 = 4.0 / 3.0
W2 = -1.0 / 12.0

# Halo radius.
RADIUS = 2

# Block-size variants compiled by aot.py (interior n = 128).
WAVE_VARIANTS = [
    (8, 8),
    (16, 16),
    (32, 32),
    (64, 64),
    (128, 128),
    (16, 64),
    (64, 16),
]


def _wave_kernel(c_ref, p_ref, v_ref, o_ref, *, bm: int, bn: int):
    """One (bm, bn) tile of the leapfrog update."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    win = pl.load(
        c_ref,
        (pl.dslice(i * bm, bm + 2 * RADIUS), pl.dslice(j * bn, bn + 2 * RADIUS)),
    )
    c = win[2:-2, 2:-2]
    lap = (
        2.0 * W0 * c
        + W1 * (win[1:-3, 2:-2] + win[3:-1, 2:-2] + win[2:-2, 1:-3] + win[2:-2, 3:-1])
        + W2 * (win[:-4, 2:-2] + win[4:, 2:-2] + win[2:-2, :-4] + win[2:-2, 4:])
    )
    prv = p_ref[...]
    vf = v_ref[...]
    o_ref[...] = 2.0 * c - prv + vf * lap


def wave_step_tiles(curr_padded, prev, vfact, bm: int, bn: int):
    """One leapfrog step; returns the (n, n) next interior field.

    ``curr_padded``: (n+4, n+4); ``prev``/``vfact``: (n, n).
    """
    n = curr_padded.shape[0] - 2 * RADIUS
    assert prev.shape == (n, n) and vfact.shape == (n, n)
    assert n % bm == 0 and n % bn == 0, f"{bm}x{bn} must divide {n}"
    grid = (n // bm, n // bn)
    return pl.pallas_call(
        partial(_wave_kernel, bm=bm, bn=bn),
        out_shape=jax.ShapeDtypeStruct((n, n), curr_padded.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec(curr_padded.shape, lambda i, j: (0, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(curr_padded, prev, vfact)


def vmem_bytes(bm: int, bn: int, dtype_bytes: int = 4) -> int:
    """VMEM working-set estimate: halo window + prev + vfact + out tiles."""
    h2 = 2 * RADIUS
    return dtype_bytes * ((bm + h2) * (bn + h2) + 3 * bm * bn)
