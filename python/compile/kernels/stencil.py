"""L1 Pallas kernel: tiled red-black Gauss-Seidel colour sweep.

The paper's SS3 example parallelises the red-black sweep with
``schedule(dynamic, chunk)`` on a CPU. The TPU-shaped analogue of that
granularity knob (DESIGN.md SSHardware-Adaptation) is the Pallas ``BlockSpec``
tile ``(bm, bn)``: it fixes the HBM->VMEM window each grid step stages, just
as ``chunk`` fixes the iteration window each OpenMP thread claims. The
auto-tuner picks among AOT-compiled ``(bm, bn)`` variants at runtime.

Kernel contract (one colour phase of the sweep):

    out[i, j] = 0.25 * (p[i-1,j] + p[i+1,j] + p[i,j-1] + p[i,j+1])
                                        if (i + j) % 2 == colour
    out[i, j] = p[i, j]                 otherwise

with ``p`` the padded ``(n+2, n+2)`` grid (fixed Dirichlet ring) and ``out``
the ``(n, n)`` interior, indices 1-based on the padded grid to match the
Rust substrate's colouring exactly.

All ``pallas_call``s use ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes byte-identically.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block-size variants compiled by aot.py. Every (bm, bn) must divide the
# interior size n. VMEM working set per grid step is
# (bm+2)*(bn+2 [input window]) + bm*bn [output] floats.
RB_VARIANTS = [
    (8, 8),
    (16, 16),
    (32, 32),
    (64, 64),
    (128, 128),
    (32, 128),
    (128, 32),
    (256, 256),
]


def _rb_colour_kernel(p_ref, o_ref, *, colour: int, bm: int, bn: int):
    """One (bm, bn) output tile of the colour-sweep.

    ``p_ref`` holds the full padded grid (the interpret-mode stand-in for a
    VMEM-staged halo window); ``o_ref`` is this program's output tile.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    # Stage the (bm+2, bn+2) halo window for this tile.
    win = pl.load(p_ref, (pl.dslice(i * bm, bm + 2), pl.dslice(j * bn, bn + 2)))
    centre = win[1:-1, 1:-1]
    new = 0.25 * (win[:-2, 1:-1] + win[2:, 1:-1] + win[1:-1, :-2] + win[1:-1, 2:])
    # Global (padded-grid) coordinates of the tile's cells: rows i*bm+1 ...
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + i * bm + 1
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + j * bn + 1
    mask = ((rows + cols) % 2) == colour
    o_ref[...] = jnp.where(mask, new, centre)


def rb_colour_step(padded, colour: int, bm: int, bn: int):
    """Apply one colour phase; returns the updated (n, n) interior.

    ``padded``: (n+2, n+2) float32, n divisible by bm and bn.
    """
    n = padded.shape[0] - 2
    assert padded.shape == (n + 2, n + 2), "padded grid must be square"
    assert n % bm == 0 and n % bn == 0, f"{bm}x{bn} must divide {n}"
    grid = (n // bm, n // bn)
    return pl.pallas_call(
        partial(_rb_colour_kernel, colour=colour, bm=bm, bn=bn),
        out_shape=jax.ShapeDtypeStruct((n, n), padded.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec(padded.shape, lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(padded)


def vmem_bytes(bm: int, bn: int, halo: int = 1, dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set of one grid step (input window + output
    tile), used for the SSPerf roofline notes in DESIGN.md/EXPERIMENTS.md."""
    h2 = 2 * halo
    return dtype_bytes * ((bm + h2) * (bn + h2) + bm * bn)
