# L2 package marker: keeps `pip install -e python` able to discover the
# package (setuptools ignores directories without __init__.py). Submodules
# import jax lazily at their own import time, not here.
